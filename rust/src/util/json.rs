//! Minimal JSON parser/serializer (serde is unavailable in the offline
//! vendored crate set, so the manifest/config plumbing is built on this).
//!
//! Supports the full JSON grammar minus exotic escapes (\u is decoded for
//! the BMP only, which is all the manifest ever contains).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Shape-style arrays (`[1, 2, 256]`) as Vec<usize>.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line serialization (JSONL records, BENCH_JSON lines).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    e.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 1 {
                            out.push(' ');
                        }
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.s.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.s.len()
            && matches!(self.s[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode multi-byte utf8 runs
                    let start = self.i - 1;
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = utf8_len(c);
                        self.i = start + len;
                        out.push_str(std::str::from_utf8(&self.s[start..self.i])?);
                    }
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?} at byte {}", c as char, self.i),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": {"d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x\ny");
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn shapes() {
        let v = Json::parse("[1, 2, 256]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![1, 2, 256]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{bad}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }
}
