//! Deterministic PRNG (SplitMix64 -> xoshiro256++), plus the sampling
//! helpers the tuner's evolutionary search needs. Built in-tree because the
//! offline crate set has no `rand`.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_rangeish() {
        let mut r = Rng::seed(1);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[r.below(8)] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
