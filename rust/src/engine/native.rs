//! Native engine backend: the transformer computed in-process by
//! `crate::kernel` — no PJRT, no AOT artifacts, no XLA extension.
//!
//! Two things distinguish it from the reference engine (which it matches
//! numerically, operation for operation):
//!
//! * It runs over the real `CacheBackend` arms. KV state is stored
//!   *actually quantized* (packed codes + scales, via `kernel::quantize`,
//!   the same `quant::asym` math the PJRT quant executables implement), in
//!   either the dense slot buffers or the paged block pool — so paged
//!   serving semantics (admission, preemption, prefix sharing, swap) are
//!   identical across backends.
//! * Attention never builds a dense staging copy: `kernel::attend_one`
//!   walks the cache's `KvView` — block tables on the paged arm —
//!   dequantizing each page inside the accumulation loops. The
//!   `gather_bytes` counter is structurally zero here, which is the whole
//!   point (see `table10_kernel`).
//!
//! Prefill is token-by-token, which on kivi layers commits each full group
//! before later tokens attend — the same prefill-stage error-accumulation
//! semantics the paper calibrates with (App. C) and the reference engine
//! implements.

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig};
use crate::kernel;
use crate::kvcache::{CacheBackend, KvCache, PagedKvCache, PagedOptions};
use crate::model::Weights;
use crate::tensor::Tensor;

pub struct NativeEngine {
    pub cfg: ModelConfig,
    pub specs: Vec<LayerSpec>,
    weights: Weights,
    /// Dense reference arm or the paged block-pool arm, behind one interface.
    pub cache: Box<dyn CacheBackend>,
    pub batch: usize,
    pub s_max: usize,
    /// Kept for the scheduler's preemption cost model; native prefill is
    /// token-by-token, so this does not change numerics.
    pub prefill_chunk: usize,
    /// Logits of the last step per slot (for perplexity / eval paths).
    pub last_logits: Vec<Vec<f32>>,
}

impl NativeEngine {
    /// Build a native engine. `paged: None` = dense reference arm,
    /// `Some(opts)` = paged block pool (admission/preemption/prefix sharing
    /// exactly as under the XLA backend).
    pub fn new(
        cfg: &ModelConfig,
        weights: Weights,
        specs: Vec<LayerSpec>,
        batch: usize,
        s_max: usize,
        prefill_chunk: usize,
        paged: Option<PagedOptions>,
    ) -> Result<NativeEngine> {
        anyhow::ensure!(specs.len() == cfg.n_layers, "one spec per layer");
        anyhow::ensure!(batch > 0, "batch must be > 0");
        weights.validate(cfg)?;
        let cache: Box<dyn CacheBackend> = match paged {
            None => Box::new(KvCache::new(cfg, &specs, batch, s_max)?),
            Some(opts) => Box::new(PagedKvCache::new(cfg, &specs, batch, s_max, &opts)?),
        };
        Ok(NativeEngine {
            cfg: cfg.clone(),
            specs,
            weights,
            cache,
            batch,
            s_max,
            prefill_chunk,
            last_logits: vec![Vec::new(); batch],
        })
    }

    /// Run one token through every layer for `slot`: project, rope, commit
    /// K/V quantized-at-storage, then attend block-table-direct. Returns the
    /// final hidden state; the caller advances the slot's position.
    fn forward_token(&mut self, slot: usize, token: i32) -> Result<Vec<f32>> {
        let (d, hq, hkv, dh, ff) = (
            self.cfg.d_model,
            self.cfg.n_heads,
            self.cfg.n_kv_heads,
            self.cfg.head_dim,
            self.cfg.d_ff,
        );
        let eps = self.cfg.rms_eps as f32;
        let theta = self.cfg.rope_theta;
        let g = self.cfg.group;
        let n_layers = self.cfg.n_layers;
        let pos = self.cache.pos(slot) as usize;
        anyhow::ensure!(pos < self.s_max, "cache capacity {} exceeded", self.s_max);
        anyhow::ensure!((token as usize) < self.cfg.vocab, "token id {token} out of range");

        let mut x = {
            let emb = self.weights.embed()?.as_f32()?;
            emb[(token as usize) * d..(token as usize + 1) * d].to_vec()
        };

        let mut h = vec![0f32; d];
        let mut q = vec![0f32; hq * dh];
        let mut k = vec![0f32; hkv * dh];
        let mut v = vec![0f32; hkv * dh];
        let mut attn_out = vec![0f32; hq * dh];
        let mut proj = vec![0f32; d];
        let mut mlp_h = vec![0f32; ff];

        for l in 0..n_layers {
            let spec = self.specs[l];
            let lw = self.weights.layer(l)?;
            let (ln1, wq, wk, wv, wo, ln2, w1, w2) = (
                lw[0].as_f32()?,
                lw[1].as_f32()?,
                lw[2].as_f32()?,
                lw[3].as_f32()?,
                lw[4].as_f32()?,
                lw[5].as_f32()?,
                lw[6].as_f32()?,
                lw[7].as_f32()?,
            );
            kernel::rms_norm(&x, ln1, eps, &mut h);
            q.fill(0.0);
            k.fill(0.0);
            v.fill(0.0);
            kernel::matvec_acc(&h, wq, d, hq * dh, &mut q);
            kernel::matvec_acc(&h, wk, d, hkv * dh, &mut k);
            kernel::matvec_acc(&h, wv, d, hkv * dh, &mut v);
            kernel::apply_rope_heads(&mut q, hq, dh, pos, theta);
            kernel::apply_rope_heads(&mut k, hkv, dh, pos, theta);

            // commit the new token to the cache, quantized per the layer spec
            match spec.mode {
                Mode::Fp => {
                    let kt = Tensor::f32(&[1, hkv, 1, dh], k.clone());
                    let vt = Tensor::f32(&[1, hkv, 1, dh], v.clone());
                    self.cache.append_fp(l, slot, &kt, &vt, &[1])?;
                }
                Mode::Token => {
                    let outs = kernel::token_step_outputs(&k, &v, hkv, dh, spec.pair)?;
                    self.cache.append_token_outputs(l, slot, &outs, &[1])?;
                }
                Mode::Kivi => {
                    let kt = Tensor::f32(&[1, hkv, 1, dh], k.clone());
                    let vt = Tensor::f32(&[1, hkv, 1, dh], v.clone());
                    let commit = self.cache.append_kivi_residual(l, slot, &kt, &vt, &[1])?;
                    if commit[0] {
                        let (kchunk, vchunk) = self.cache.residual_chunk(l, slot)?;
                        let (k_outs, v_outs) =
                            kernel::kivi_commit_outputs(&kchunk, &vchunk, hkv, g, dh, spec.pair)?;
                        self.cache.commit_kivi_chunk(l, slot, &k_outs, &v_outs)?;
                    }
                }
            }

            // dequant-on-read attention over committed pages + residual —
            // no dense staging buffer on this path
            {
                let view = self.cache.kv_view(l, slot)?;
                kernel::attend_one(&q, hq, &view, &mut attn_out)?;
            }

            proj.fill(0.0);
            kernel::matvec_acc(&attn_out, wo, hq * dh, d, &mut proj);
            for i in 0..d {
                x[i] += proj[i];
            }

            kernel::rms_norm(&x, ln2, eps, &mut h);
            mlp_h.fill(0.0);
            kernel::matvec_acc(&h, w1, d, ff, &mut mlp_h);
            kernel::gelu_tanh_inplace(&mut mlp_h);
            proj.fill(0.0);
            kernel::matvec_acc(&mlp_h, w2, ff, d, &mut proj);
            for i in 0..d {
                x[i] += proj[i];
            }
        }
        Ok(x)
    }

    /// Final norm + tied-embedding head: (argmax token, full logits).
    fn lm_head(&self, x: &[f32]) -> Result<(i32, Vec<f32>)> {
        let d = self.cfg.d_model;
        let eps = self.cfg.rms_eps as f32;
        let mut h = vec![0f32; d];
        kernel::rms_norm(x, self.weights.ln_f()?.as_f32()?, eps, &mut h);
        let emb = self.weights.embed()?.as_f32()?;
        let vocab = self.cfg.vocab;
        let mut logits = vec![0f32; vocab];
        let mut best = (0usize, f32::NEG_INFINITY);
        for t in 0..vocab {
            let row = &emb[t * d..(t + 1) * d];
            let mut dot = 0f32;
            for i in 0..d {
                dot += h[i] * row[i];
            }
            logits[t] = dot;
            if dot > best.1 {
                best = (t, dot);
            }
        }
        Ok((best.0 as i32, logits))
    }

    /// One decode step over the whole batch (slots are independent, so the
    /// native backend steps them sequentially — numerics identical to a
    /// batched step). Returns the argmax next token per slot.
    pub fn decode_step(&mut self, tokens: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        anyhow::ensure!(tokens.len() == self.batch && active.len() == self.batch);
        let mut out = vec![0i32; self.batch];
        for b in 0..self.batch {
            if !active[b] {
                continue;
            }
            let x = self.forward_token(b, tokens[b])?;
            let (next, logits) = self.lm_head(&x)?;
            self.last_logits[b] = logits;
            self.cache.advance_pos(b, 1);
            out[b] = next;
        }
        Ok(out)
    }

    /// Prefill a slot token by token (kivi groups commit as they fill, so
    /// later prompt tokens attend over already-quantized earlier ones).
    /// Returns the first generated token.
    pub fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<i32> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            (self.cache.pos(slot) as usize + prompt.len()) <= self.s_max,
            "prompt overflows cache"
        );
        let mut last_x = Vec::new();
        for &t in prompt {
            last_x = self.forward_token(slot, t)?;
            self.cache.advance_pos(slot, 1);
        }
        let (next, logits) = self.lm_head(&last_x)?;
        self.last_logits[slot] = logits;
        Ok(next)
    }

    /// Greedy generation for one slot (prefill + decode).
    pub fn generate(&mut self, slot: usize, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        self.cache.reset_slot(slot);
        let mut next = self.prefill(slot, prompt)?;
        let mut out = Vec::with_capacity(max_new);
        let mut tokens = vec![0i32; self.batch];
        let mut active = vec![false; self.batch];
        active[slot] = true;
        for _ in 0..max_new {
            out.push(next);
            if self.cache.pos(slot) as usize >= self.s_max {
                break;
            }
            tokens[slot] = next;
            next = self.decode_step(&tokens, &active)?[slot];
        }
        Ok(out)
    }

    pub fn kv_bytes(&self) -> usize {
        self.cache.kv_bytes()
    }

    pub fn equivalent_bits(&self) -> f64 {
        self.cache.equivalent_bits()
    }
}

impl super::EngineCore for NativeEngine {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn s_max(&self) -> usize {
        self.s_max
    }

    fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    fn cache(&self) -> &dyn CacheBackend {
        self.cache.as_ref()
    }

    fn cache_mut(&mut self) -> &mut dyn CacheBackend {
        self.cache.as_mut()
    }

    fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<i32> {
        NativeEngine::prefill(self, slot, prompt)
    }

    fn decode_step(&mut self, tokens: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        NativeEngine::decode_step(self, tokens, active)
    }

    fn logits(&self, slot: usize) -> &[f32] {
        &self.last_logits[slot]
    }

    fn generate(&mut self, slot: usize, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        NativeEngine::generate(self, slot, prompt, max_new)
    }
}
