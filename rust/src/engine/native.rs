//! Native engine backend: the transformer computed in-process by
//! `crate::kernel` — no PJRT, no AOT artifacts, no XLA extension.
//!
//! Three things distinguish it from the reference engine (which it matches
//! numerically, operation for operation):
//!
//! * It runs over the real `CacheBackend` arms. KV state is stored
//!   *actually quantized* (packed codes + scales, via `kernel::quantize`,
//!   the same `quant::asym` math the PJRT quant executables implement), in
//!   either the dense slot buffers or the paged block pool — so paged
//!   serving semantics (admission, preemption, prefix sharing, swap) are
//!   identical across backends.
//! * Attention never builds a dense staging copy: `kernel::attend_one_mt`
//!   walks the cache's `KvView` — block tables on the paged arm —
//!   dequantizing each page inside the accumulation loops. The
//!   `gather_bytes` counter is structurally zero here, which is the whole
//!   point (see `table10_kernel`).
//! * Execution is parallel but deterministic: every hot kernel runs over an
//!   in-tree thread pool partitioned by *outputs* (column ranges, query
//!   heads, row blocks), so logits are bit-identical for any `threads`
//!   value and `--threads 1` reproduces the scalar engine exactly
//!   (`tests/native_backend.rs` pins this, `table11_native_mt` measures
//!   it).
//!
//! Prefill runs in KIVI-group-sized row blocks (`prefill_block`): one fused
//! QKV `matmul` + `attend_block` causal pass per group, with each kivi
//! group committing at exactly the boundary the token-by-token path commits
//! — identical numerics, ~group× fewer weight-matrix passes. The
//! token-by-token path survives as `prefill_tokenwise`, the parity oracle.
//!
//! Decode is batched the same way: all active slots gather into `[nb, d]`
//! rows and every layer runs one fused `matmul` + `attend_many` pass
//! (`decode_batch`), one weight pass for the whole batch. The per-slot
//! loop survives as `decode_step_sequential`, the bitwise oracle the
//! differential-churn harness (`tests/batched_decode.rs`) drives against
//! the batched scheduler. Decode steps allocate nothing proportional to
//! batch or step count: next tokens, logits and all layer scratch live in
//! the engine (plus thread-local kernel scratch), refilled in place each
//! step (`table11_native_mt` pins this with a counting allocator).

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig};
use crate::kernel::{self, ThreadPool};
use crate::kvcache::{CacheBackend, KvCache, PagedKvCache, PagedOptions};
use crate::model::Weights;
use crate::obs::{CounterHandle, Phase, ProbeConfig, ProfileSnapshot, Profiler, SensitivityProbe};
use crate::tensor::Tensor;

/// Engine-resident scratch: sized once at construction so the decode loop
/// and the block-prefill loop run allocation-free (cache-append `Tensor`
/// staging aside, which is part of the `CacheBackend` API surface).
struct Scratch {
    // decode / tokenwise-prefill (one token)
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    mlp: Vec<f32>,
    /// Final-norm buffer for the lm head (separate from `h` so the head can
    /// read `x` while writing it).
    head_h: Vec<f32>,
    // block prefill (cfg.group rows)
    xs: Vec<f32>,
    hs: Vec<f32>,
    qs: Vec<f32>,
    ks: Vec<f32>,
    vs: Vec<f32>,
    attns: Vec<f32>,
    projs: Vec<f32>,
    mlps: Vec<f32>,
    /// Head-major `[h, g, dh]` staging for the cache-append tensor layouts.
    kt: Vec<f32>,
    vt: Vec<f32>,
}

/// Batch-dimension scratch for the batched decode step: `[batch, ...]`
/// row-major buffers sized once at construction, plus the gathered
/// active-slot list and the engine-resident next-token output (the buffer
/// `decode_step` used to allocate every step).
struct BatchScratch {
    xs: Vec<f32>,
    hs: Vec<f32>,
    qs: Vec<f32>,
    ks: Vec<f32>,
    vs: Vec<f32>,
    attns: Vec<f32>,
    projs: Vec<f32>,
    mlps: Vec<f32>,
    head_hs: Vec<f32>,
    /// Active slot indices, ascending — the gather that folds a sparse
    /// `active` mask into dense `[nb, ...]` rows.
    act: Vec<usize>,
    /// Argmax next token per *slot* (not per gathered row).
    out: Vec<i32>,
}

impl BatchScratch {
    fn new(cfg: &ModelConfig, batch: usize) -> BatchScratch {
        let (d, hq, hkv, dh, ff) =
            (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff);
        BatchScratch {
            xs: vec![0.0; batch * d],
            hs: vec![0.0; batch * d],
            qs: vec![0.0; batch * hq * dh],
            ks: vec![0.0; batch * hkv * dh],
            vs: vec![0.0; batch * hkv * dh],
            attns: vec![0.0; batch * hq * dh],
            projs: vec![0.0; batch * d],
            mlps: vec![0.0; batch * ff],
            head_hs: vec![0.0; batch * d],
            act: Vec::with_capacity(batch),
            out: vec![0; batch],
        }
    }
}

impl Scratch {
    fn new(cfg: &ModelConfig) -> Scratch {
        let (d, hq, hkv, dh, ff, g) = (
            cfg.d_model,
            cfg.n_heads,
            cfg.n_kv_heads,
            cfg.head_dim,
            cfg.d_ff,
            cfg.group,
        );
        Scratch {
            x: vec![0.0; d],
            h: vec![0.0; d],
            q: vec![0.0; hq * dh],
            k: vec![0.0; hkv * dh],
            v: vec![0.0; hkv * dh],
            attn: vec![0.0; hq * dh],
            proj: vec![0.0; d],
            mlp: vec![0.0; ff],
            head_h: vec![0.0; d],
            xs: vec![0.0; g * d],
            hs: vec![0.0; g * d],
            qs: vec![0.0; g * hq * dh],
            ks: vec![0.0; g * hkv * dh],
            vs: vec![0.0; g * hkv * dh],
            attns: vec![0.0; g * hq * dh],
            projs: vec![0.0; g * d],
            mlps: vec![0.0; g * ff],
            kt: vec![0.0; hkv * g * dh],
            vt: vec![0.0; hkv * g * dh],
        }
    }
}

/// Run one token through every layer for `slot`: project, rope, commit K/V
/// quantized-at-storage, then attend block-table-direct. Leaves the final
/// hidden state in `sc.x`; the caller advances the slot's position.
#[allow(clippy::too_many_arguments)]
fn forward_token(
    cfg: &ModelConfig,
    specs: &[LayerSpec],
    weights: &Weights,
    cache: &mut dyn CacheBackend,
    pool: &ThreadPool,
    prof: &Profiler,
    probe: &mut SensitivityProbe,
    sc: &mut Scratch,
    slot: usize,
    token: i32,
) -> Result<()> {
    let (d, hq, hkv, dh, ff) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff);
    let eps = cfg.rms_eps as f32;
    let theta = cfg.rope_theta;
    let g = cfg.group;
    let pos = cache.pos(slot) as usize;
    anyhow::ensure!(pos < cache.s_max(), "cache capacity {} exceeded", cache.s_max());
    anyhow::ensure!((token as usize) < cfg.vocab, "token id {token} out of range");

    {
        let emb = weights.embed()?.as_f32()?;
        sc.x.copy_from_slice(&emb[(token as usize) * d..(token as usize + 1) * d]);
    }

    for l in 0..cfg.n_layers {
        let spec = specs[l];
        let lw = weights.layer(l)?;
        let (ln1, wq, wk, wv, wo, ln2, w1, w2) = (
            lw[0].as_f32()?,
            lw[1].as_f32()?,
            lw[2].as_f32()?,
            lw[3].as_f32()?,
            lw[4].as_f32()?,
            lw[5].as_f32()?,
            lw[6].as_f32()?,
            lw[7].as_f32()?,
        );
        let t_qkv = prof.start();
        kernel::rms_norm(&sc.x, ln1, eps, &mut sc.h);
        sc.q.fill(0.0);
        sc.k.fill(0.0);
        sc.v.fill(0.0);
        kernel::matvec_acc_mt(pool, &sc.h, wq, d, hq * dh, &mut sc.q);
        kernel::matvec_acc_mt(pool, &sc.h, wk, d, hkv * dh, &mut sc.k);
        kernel::matvec_acc_mt(pool, &sc.h, wv, d, hkv * dh, &mut sc.v);
        kernel::apply_rope_heads(&mut sc.q, hq, dh, pos, theta);
        kernel::apply_rope_heads(&mut sc.k, hkv, dh, pos, theta);
        prof.stop(l, Phase::Qkv, t_qkv);
        // fp shadow of the row before quantize-at-commit (no-op when the
        // probe is disabled; read-only w.r.t. the forward pass when enabled)
        probe.record_row(l, slot, pos, &sc.q, &sc.k, &sc.v);

        // commit the new token to the cache, quantized per the layer spec
        let t_quant = prof.start();
        match spec.mode {
            Mode::Fp => {
                let kt = Tensor::f32(&[1, hkv, 1, dh], sc.k.clone());
                let vt = Tensor::f32(&[1, hkv, 1, dh], sc.v.clone());
                cache.append_fp(l, slot, &kt, &vt, &[1])?;
            }
            Mode::Token => {
                let outs = kernel::token_step_outputs(&sc.k, &sc.v, hkv, dh, spec.pair)?;
                cache.append_token_outputs(l, slot, &outs, &[1])?;
            }
            Mode::Kivi => {
                let kt = Tensor::f32(&[1, hkv, 1, dh], sc.k.clone());
                let vt = Tensor::f32(&[1, hkv, 1, dh], sc.v.clone());
                let commit = cache.append_kivi_residual(l, slot, &kt, &vt, &[1])?;
                if commit[0] {
                    let (kchunk, vchunk) = cache.residual_chunk(l, slot)?;
                    let (k_outs, v_outs) =
                        kernel::kivi_commit_outputs(&kchunk, &vchunk, hkv, g, dh, spec.pair)?;
                    cache.commit_kivi_chunk(l, slot, &k_outs, &v_outs)?;
                }
            }
        }
        prof.stop(l, Phase::QuantCommit, t_quant);

        // dequant-on-read attention over committed pages + residual —
        // no dense staging buffer on this path
        let t_att = prof.start();
        {
            let view = cache.kv_view(l, slot)?;
            kernel::attend_one_mt(pool, &sc.q, hq, &view, &mut sc.attn)?;
        }

        sc.proj.fill(0.0);
        kernel::matvec_acc_mt(pool, &sc.attn, wo, hq * dh, d, &mut sc.proj);
        for i in 0..d {
            sc.x[i] += sc.proj[i];
        }
        prof.stop(l, Phase::Attend, t_att);

        let t_mlp = prof.start();
        kernel::rms_norm(&sc.x, ln2, eps, &mut sc.h);
        sc.mlp.fill(0.0);
        kernel::matvec_acc_mt(pool, &sc.h, w1, d, ff, &mut sc.mlp);
        kernel::gelu_tanh_inplace(&mut sc.mlp);
        sc.proj.fill(0.0);
        kernel::matvec_acc_mt(pool, &sc.mlp, w2, ff, d, &mut sc.proj);
        for i in 0..d {
            sc.x[i] += sc.proj[i];
        }
        prof.stop(l, Phase::Mlp, t_mlp);
    }
    Ok(())
}

/// One decode step for all active slots at once: the gathered `[nb, d]`
/// hidden rows run each layer as one fused pass — `rms_norm_rows` +
/// `matmul` QKV/MLP (one weight pass for the whole batch), per-slot rope /
/// commit at each slot's own position, and `attend_many` over all block
/// tables in one pool dispatch. Every per-output accumulation is the exact
/// `forward_token` + `lm_head` loop (one-row `matmul` ≡ `matvec_acc`,
/// `attend_many` reuses `attend_head`, `matvec_rows_many` dots are
/// `matvec_rows` dots), so the step is bit-identical to stepping the slots
/// sequentially — the `decode_step_sequential` oracle — at any thread
/// count and any batch shape. Writes each slot's next token into `sc.out`;
/// the caller advances positions.
#[allow(clippy::too_many_arguments)]
fn decode_batch(
    cfg: &ModelConfig,
    specs: &[LayerSpec],
    weights: &Weights,
    cache: &mut dyn CacheBackend,
    pool: &ThreadPool,
    prof: &Profiler,
    probe: &mut SensitivityProbe,
    sc: &mut BatchScratch,
    tokens: &[i32],
    active: &[bool],
    last_logits: &mut [Vec<f32>],
) -> Result<()> {
    let (d, hq, hkv, dh, ff) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff);
    let eps = cfg.rms_eps as f32;
    let theta = cfg.rope_theta;
    let g = cfg.group;
    let (stride_q, stride_kv) = (hq * dh, hkv * dh);

    sc.act.clear();
    sc.act.extend(active.iter().enumerate().filter(|(_, &a)| a).map(|(b, _)| b));
    let nb = sc.act.len();
    if nb == 0 {
        return Ok(());
    }
    {
        let emb = weights.embed()?.as_f32()?;
        for (i, &slot) in sc.act.iter().enumerate() {
            let pos = cache.pos(slot) as usize;
            anyhow::ensure!(pos < cache.s_max(), "cache capacity {} exceeded", cache.s_max());
            let tok = tokens[slot];
            anyhow::ensure!((tok as usize) < cfg.vocab, "token id {tok} out of range");
            sc.xs[i * d..(i + 1) * d]
                .copy_from_slice(&emb[(tok as usize) * d..(tok as usize + 1) * d]);
        }
    }

    for l in 0..cfg.n_layers {
        let spec = specs[l];
        let lw = weights.layer(l)?;
        let (ln1, wq, wk, wv, wo, ln2, w1, w2) = (
            lw[0].as_f32()?,
            lw[1].as_f32()?,
            lw[2].as_f32()?,
            lw[3].as_f32()?,
            lw[4].as_f32()?,
            lw[5].as_f32()?,
            lw[6].as_f32()?,
            lw[7].as_f32()?,
        );
        let t_qkv = prof.start();
        kernel::rms_norm_rows(pool, &sc.xs[..nb * d], ln1, eps, nb, d, &mut sc.hs[..nb * d]);
        kernel::matmul_mt(pool, &sc.hs[..nb * d], wq, nb, d, stride_q, &mut sc.qs[..nb * stride_q]);
        kernel::matmul_mt(
            pool,
            &sc.hs[..nb * d],
            wk,
            nb,
            d,
            stride_kv,
            &mut sc.ks[..nb * stride_kv],
        );
        kernel::matmul_mt(
            pool,
            &sc.hs[..nb * d],
            wv,
            nb,
            d,
            stride_kv,
            &mut sc.vs[..nb * stride_kv],
        );
        for (i, &slot) in sc.act.iter().enumerate() {
            let pos = cache.pos(slot) as usize;
            kernel::apply_rope_heads(
                &mut sc.qs[i * stride_q..(i + 1) * stride_q],
                hq,
                dh,
                pos,
                theta,
            );
            kernel::apply_rope_heads(
                &mut sc.ks[i * stride_kv..(i + 1) * stride_kv],
                hkv,
                dh,
                pos,
                theta,
            );
        }
        prof.stop(l, Phase::Qkv, t_qkv);

        // per-slot probe shadow + quantize-at-commit, in ascending slot
        // order — the order the sequential loop feeds each layer's probe
        // accumulators, so even observability sums stay bit-identical
        let t_quant = prof.start();
        for (i, &slot) in sc.act.iter().enumerate() {
            let pos = cache.pos(slot) as usize;
            let qrow = &sc.qs[i * stride_q..(i + 1) * stride_q];
            let krow = &sc.ks[i * stride_kv..(i + 1) * stride_kv];
            let vrow = &sc.vs[i * stride_kv..(i + 1) * stride_kv];
            probe.record_row(l, slot, pos, qrow, krow, vrow);
            match spec.mode {
                Mode::Fp => {
                    let kt = Tensor::f32(&[1, hkv, 1, dh], krow.to_vec());
                    let vt = Tensor::f32(&[1, hkv, 1, dh], vrow.to_vec());
                    cache.append_fp(l, slot, &kt, &vt, &[1])?;
                }
                Mode::Token => {
                    let outs = kernel::token_step_outputs(krow, vrow, hkv, dh, spec.pair)?;
                    cache.append_token_outputs(l, slot, &outs, &[1])?;
                }
                Mode::Kivi => {
                    let kt = Tensor::f32(&[1, hkv, 1, dh], krow.to_vec());
                    let vt = Tensor::f32(&[1, hkv, 1, dh], vrow.to_vec());
                    let commit = cache.append_kivi_residual(l, slot, &kt, &vt, &[1])?;
                    if commit[0] {
                        let (kchunk, vchunk) = cache.residual_chunk(l, slot)?;
                        let (k_outs, v_outs) =
                            kernel::kivi_commit_outputs(&kchunk, &vchunk, hkv, g, dh, spec.pair)?;
                        cache.commit_kivi_chunk(l, slot, &k_outs, &v_outs)?;
                    }
                }
            }
        }
        prof.stop(l, Phase::QuantCommit, t_quant);

        let t_att = prof.start();
        {
            // all commits done: take every active slot's view at once (the
            // views borrow the cache immutably) and attend in one dispatch
            let cache_ref: &dyn CacheBackend = &*cache;
            let views = sc
                .act
                .iter()
                .map(|&slot| cache_ref.kv_view(l, slot))
                .collect::<Result<Vec<_>>>()?;
            kernel::attend_many(
                pool,
                &sc.qs[..nb * stride_q],
                hq,
                &views,
                &mut sc.attns[..nb * stride_q],
            )?;
        }
        kernel::matmul_mt(
            pool,
            &sc.attns[..nb * stride_q],
            wo,
            nb,
            stride_q,
            d,
            &mut sc.projs[..nb * d],
        );
        for i in 0..nb * d {
            sc.xs[i] += sc.projs[i];
        }
        prof.stop(l, Phase::Attend, t_att);

        let t_mlp = prof.start();
        kernel::rms_norm_rows(pool, &sc.xs[..nb * d], ln2, eps, nb, d, &mut sc.hs[..nb * d]);
        kernel::matmul_mt(pool, &sc.hs[..nb * d], w1, nb, d, ff, &mut sc.mlps[..nb * ff]);
        kernel::gelu_tanh_inplace(&mut sc.mlps[..nb * ff]);
        kernel::matmul_mt(pool, &sc.mlps[..nb * ff], w2, nb, ff, d, &mut sc.projs[..nb * d]);
        for i in 0..nb * d {
            sc.xs[i] += sc.projs[i];
        }
        prof.stop(l, Phase::Mlp, t_mlp);
    }

    // batched lm head: one pass over the tied-embedding rows for the whole
    // batch, each slot's logits row refilled in place, then the sequential
    // first-max-wins argmax per slot
    let t_head = prof.start();
    kernel::rms_norm_rows(
        pool,
        &sc.xs[..nb * d],
        weights.ln_f()?.as_f32()?,
        eps,
        nb,
        d,
        &mut sc.head_hs[..nb * d],
    );
    {
        let emb = weights.embed()?.as_f32()?;
        let mut ys: Vec<&mut [f32]> = Vec::with_capacity(nb);
        let mut rest: &mut [Vec<f32>] = last_logits;
        let mut base = 0usize;
        for &slot in &sc.act {
            let (head, tail) = rest.split_at_mut(slot - base + 1);
            ys.push(head[slot - base].as_mut_slice());
            rest = tail;
            base = slot + 1;
        }
        kernel::matvec_rows_many_mt(
            pool,
            emb,
            &sc.head_hs[..nb * d],
            nb,
            cfg.vocab,
            d,
            &mut ys,
        );
    }
    for &slot in &sc.act {
        let mut best = (0usize, f32::NEG_INFINITY);
        for (t, &v) in last_logits[slot].iter().enumerate() {
            if v > best.1 {
                best = (t, v);
            }
        }
        sc.out[slot] = best.0 as i32;
    }
    prof.stop(cfg.n_layers, Phase::LmHead, t_head);
    Ok(())
}

/// Run one group-aligned block of `cfg.group` prompt tokens through every
/// layer: fused per-layer QKV `matmul`, blockwise commit, and one
/// `attend_block` causal pass — the same float ops as `cfg.group` calls of
/// `forward_token`, in the same order per output element, with each weight
/// matrix read once per block instead of once per token.
///
/// Kivi layers append the whole block to the fp residual ring, attend rows
/// `0..g-1` over old pages + the in-block fp causal tail, then commit the
/// group and attend the final row post-commit — exactly the scalar path's
/// interleaved append/commit/attend sequence. Leaves the block's final
/// hidden row in `sc.x`.
#[allow(clippy::too_many_arguments)]
fn prefill_block(
    cfg: &ModelConfig,
    specs: &[LayerSpec],
    weights: &Weights,
    cache: &mut dyn CacheBackend,
    pool: &ThreadPool,
    prof: &Profiler,
    probe: &mut SensitivityProbe,
    sc: &mut Scratch,
    slot: usize,
    tokens: &[i32],
) -> Result<()> {
    let (d, hq, hkv, dh, ff) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff);
    let eps = cfg.rms_eps as f32;
    let theta = cfg.rope_theta;
    let g = tokens.len();
    debug_assert_eq!(g, cfg.group);
    let pos = cache.pos(slot) as usize;
    debug_assert_eq!(pos % cfg.group, 0, "block prefill needs a group-aligned position");
    anyhow::ensure!(pos + g <= cache.s_max(), "cache capacity {} exceeded", cache.s_max());
    let stride_q = hq * dh;
    let stride_kv = hkv * dh;

    {
        let emb = weights.embed()?.as_f32()?;
        for (t, &tok) in tokens.iter().enumerate() {
            anyhow::ensure!((tok as usize) < cfg.vocab, "token id {tok} out of range");
            sc.xs[t * d..(t + 1) * d]
                .copy_from_slice(&emb[(tok as usize) * d..(tok as usize + 1) * d]);
        }
    }

    for l in 0..cfg.n_layers {
        let spec = specs[l];
        let lw = weights.layer(l)?;
        let (ln1, wq, wk, wv, wo, ln2, w1, w2) = (
            lw[0].as_f32()?,
            lw[1].as_f32()?,
            lw[2].as_f32()?,
            lw[3].as_f32()?,
            lw[4].as_f32()?,
            lw[5].as_f32()?,
            lw[6].as_f32()?,
            lw[7].as_f32()?,
        );
        let t_qkv = prof.start();
        kernel::rms_norm_rows(pool, &sc.xs, ln1, eps, g, d, &mut sc.hs);
        kernel::matmul_mt(pool, &sc.hs, wq, g, d, hq * dh, &mut sc.qs);
        kernel::matmul_mt(pool, &sc.hs, wk, g, d, hkv * dh, &mut sc.ks);
        kernel::matmul_mt(pool, &sc.hs, wv, g, d, hkv * dh, &mut sc.vs);
        for t in 0..g {
            kernel::apply_rope_heads(
                &mut sc.qs[t * stride_q..(t + 1) * stride_q],
                hq,
                dh,
                pos + t,
                theta,
            );
            kernel::apply_rope_heads(
                &mut sc.ks[t * stride_kv..(t + 1) * stride_kv],
                hkv,
                dh,
                pos + t,
                theta,
            );
        }
        // head-major [h, g, dh] staging for the cache-append layouts
        for t in 0..g {
            for hh in 0..hkv {
                sc.kt[(hh * g + t) * dh..(hh * g + t + 1) * dh]
                    .copy_from_slice(&sc.ks[(t * hkv + hh) * dh..(t * hkv + hh + 1) * dh]);
                sc.vt[(hh * g + t) * dh..(hh * g + t + 1) * dh]
                    .copy_from_slice(&sc.vs[(t * hkv + hh) * dh..(t * hkv + hh + 1) * dh]);
            }
        }
        prof.stop(l, Phase::Qkv, t_qkv);
        // fp shadow of the whole group pre-commit: `qs`/`kt`/`vt` are
        // already in the offline capture layouts
        probe.record_block(l, pos, &sc.qs, &sc.kt, &sc.vt);
        match spec.mode {
            Mode::Fp => {
                let t_quant = prof.start();
                let kt = Tensor::f32(&[1, hkv, g, dh], sc.kt.clone());
                let vt = Tensor::f32(&[1, hkv, g, dh], sc.vt.clone());
                cache.append_fp(l, slot, &kt, &vt, &[g])?;
                prof.stop(l, Phase::QuantCommit, t_quant);
                let t_att = prof.start();
                let view = cache.kv_view(l, slot)?;
                kernel::attend_block(pool, &sc.qs, g, hq, &view, pos, &mut sc.attns)?;
                prof.stop(l, Phase::Attend, t_att);
            }
            Mode::Token => {
                // per-token quantization is row-independent: blockwise
                // commit writes the exact bytes g single-token appends would
                let t_quant = prof.start();
                let outs = kernel::token_block_outputs(&sc.kt, &sc.vt, hkv, g, dh, spec.pair)?;
                cache.append_token_outputs(l, slot, &outs, &[g])?;
                prof.stop(l, Phase::QuantCommit, t_quant);
                let t_att = prof.start();
                let view = cache.kv_view(l, slot)?;
                kernel::attend_block(pool, &sc.qs, g, hq, &view, pos, &mut sc.attns)?;
                prof.stop(l, Phase::Attend, t_att);
            }
            Mode::Kivi => {
                let t_quant = prof.start();
                let kt = Tensor::f32(&[1, hkv, g, dh], sc.kt.clone());
                let vt = Tensor::f32(&[1, hkv, g, dh], sc.vt.clone());
                let commit = cache.append_kivi_residual(l, slot, &kt, &vt, &[g])?;
                prof.stop(l, Phase::QuantCommit, t_quant);
                let t_att = prof.start();
                {
                    // rows 0..g-1 attend pre-commit: old pages plus the
                    // in-block fp causal tail — the views the scalar path's
                    // interleaved append/attend produced, bit for bit
                    let view = cache.kv_view(l, slot)?;
                    kernel::attend_block(
                        pool,
                        &sc.qs[..(g - 1) * stride_q],
                        g - 1,
                        hq,
                        &view,
                        pos,
                        &mut sc.attns[..(g - 1) * stride_q],
                    )?;
                }
                prof.stop(l, Phase::Attend, t_att);
                // the group-filling token commits before it attends — the
                // same boundary the scalar path commits at
                debug_assert!(commit[0], "a group-aligned block must fill the group");
                let t_quant = prof.start();
                let (kchunk, vchunk) = cache.residual_chunk(l, slot)?;
                let (k_outs, v_outs) =
                    kernel::kivi_commit_outputs(&kchunk, &vchunk, hkv, cfg.group, dh, spec.pair)?;
                cache.commit_kivi_chunk(l, slot, &k_outs, &v_outs)?;
                prof.stop(l, Phase::QuantCommit, t_quant);
                let t_att = prof.start();
                let view = cache.kv_view(l, slot)?;
                kernel::attend_one_mt(
                    pool,
                    &sc.qs[(g - 1) * stride_q..],
                    hq,
                    &view,
                    &mut sc.attns[(g - 1) * stride_q..],
                )?;
                prof.stop(l, Phase::Attend, t_att);
            }
        }
        let t_att = prof.start();
        kernel::matmul_mt(pool, &sc.attns, wo, g, hq * dh, d, &mut sc.projs);
        for i in 0..g * d {
            sc.xs[i] += sc.projs[i];
        }
        prof.stop(l, Phase::Attend, t_att);
        let t_mlp = prof.start();
        kernel::rms_norm_rows(pool, &sc.xs, ln2, eps, g, d, &mut sc.hs);
        kernel::matmul_mt(pool, &sc.hs, w1, g, d, ff, &mut sc.mlps);
        kernel::gelu_tanh_inplace(&mut sc.mlps);
        kernel::matmul_mt(pool, &sc.mlps, w2, g, ff, d, &mut sc.projs);
        for i in 0..g * d {
            sc.xs[i] += sc.projs[i];
        }
        prof.stop(l, Phase::Mlp, t_mlp);
    }
    // expose the block's final hidden row for the lm head
    sc.x.copy_from_slice(&sc.xs[(g - 1) * d..g * d]);
    Ok(())
}

/// Final norm + tied-embedding head, written into the engine-resident
/// `logits` buffer (no per-step allocation): threaded row-split gemm over
/// the vocab, then a sequential first-max-wins argmax — the original
/// hand-rolled loop's comparison order exactly.
fn lm_head(
    cfg: &ModelConfig,
    weights: &Weights,
    pool: &ThreadPool,
    x: &[f32],
    h: &mut [f32],
    logits: &mut [f32],
) -> Result<i32> {
    kernel::rms_norm(x, weights.ln_f()?.as_f32()?, cfg.rms_eps as f32, h);
    let emb = weights.embed()?.as_f32()?;
    kernel::matvec_rows_mt(pool, emb, h, cfg.vocab, cfg.d_model, logits);
    let mut best = (0usize, f32::NEG_INFINITY);
    for (t, &v) in logits.iter().enumerate() {
        if v > best.1 {
            best = (t, v);
        }
    }
    Ok(best.0 as i32)
}

pub struct NativeEngine {
    pub cfg: ModelConfig,
    pub specs: Vec<LayerSpec>,
    weights: Weights,
    /// Dense reference arm or the paged block-pool arm, behind one interface.
    pub cache: Box<dyn CacheBackend>,
    pub batch: usize,
    pub s_max: usize,
    /// Kept for the scheduler's preemption cost model; native prefill
    /// blocking is group-sized, so this does not change numerics.
    pub prefill_chunk: usize,
    pool: ThreadPool,
    scratch: Scratch,
    bscratch: BatchScratch,
    /// Force the per-slot sequential decode loop (the bitwise oracle the
    /// differential-churn harness runs against the batched path).
    sequential_decode: bool,
    /// Per-layer/per-phase timers; disabled by default (zero clock reads on
    /// the hot path) and swapped in whole via `set_profiling`.
    profiler: Profiler,
    /// Online sensitivity probe; disabled by default (every hook returns
    /// immediately) and swapped in whole via `set_probe`.
    probe: SensitivityProbe,
    /// Logits of the last step per slot (for perplexity / eval paths);
    /// allocated once, refilled in place every step.
    pub last_logits: Vec<Vec<f32>>,
    /// One `layer_kv_live{layer,spec}` counter track per layer, attached
    /// via `set_counters`; empty (publication-free) by default.
    layer_tracks: Vec<CounterHandle>,
}

impl NativeEngine {
    /// Build a native engine. `paged: None` = dense reference arm,
    /// `Some(opts)` = paged block pool (admission/preemption/prefix sharing
    /// exactly as under the XLA backend). `threads` sizes the kernel pool
    /// (1 = scalar, bit-identical to any other value by the
    /// output-partitioning contract).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: &ModelConfig,
        weights: Weights,
        specs: Vec<LayerSpec>,
        batch: usize,
        s_max: usize,
        prefill_chunk: usize,
        threads: usize,
        paged: Option<PagedOptions>,
    ) -> Result<NativeEngine> {
        anyhow::ensure!(specs.len() == cfg.n_layers, "one spec per layer");
        anyhow::ensure!(batch > 0, "batch must be > 0");
        anyhow::ensure!(threads >= 1, "threads must be >= 1");
        weights.validate(cfg)?;
        let cache: Box<dyn CacheBackend> = match paged {
            None => Box::new(KvCache::new(cfg, &specs, batch, s_max)?),
            Some(opts) => Box::new(PagedKvCache::new(cfg, &specs, batch, s_max, &opts)?),
        };
        Ok(NativeEngine {
            cfg: cfg.clone(),
            specs,
            weights,
            cache,
            batch,
            s_max,
            prefill_chunk,
            pool: ThreadPool::new(threads),
            scratch: Scratch::new(cfg),
            bscratch: BatchScratch::new(cfg, batch),
            sequential_decode: false,
            profiler: Profiler::disabled(),
            probe: SensitivityProbe::disabled(),
            last_logits: vec![vec![0f32; cfg.vocab]; batch],
            layer_tracks: Vec::new(),
        })
    }

    /// Kernel pool width (1 = the scalar engine).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// One decode step over the whole batch: all active slots fold into one
    /// `[nb, d]`-row pass per layer (`decode_batch`). Returns the argmax
    /// next token per slot. Convenience wrapper over `decode_step_into`,
    /// which is the allocation-free form the serving loop calls.
    pub fn decode_step(&mut self, tokens: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        let mut out = vec![0i32; self.batch];
        self.decode_step_into(tokens, active, &mut out)?;
        Ok(out)
    }

    /// Allocation-free decode step: next tokens land in the caller's `out`
    /// (length `batch`). Dispatches to the batched path, or to the
    /// sequential oracle when `set_sequential_decode(true)` — the two are
    /// bit-identical (pinned by `tests/batched_decode.rs`).
    pub fn decode_step_into(
        &mut self,
        tokens: &[i32],
        active: &[bool],
        out: &mut [i32],
    ) -> Result<()> {
        anyhow::ensure!(
            tokens.len() == self.batch && active.len() == self.batch && out.len() == self.batch
        );
        if self.sequential_decode {
            self.decode_step_sequential(tokens, active, out)?;
        } else {
            decode_batch(
                &self.cfg,
                &self.specs,
                &self.weights,
                self.cache.as_mut(),
                &self.pool,
                &self.profiler,
                &mut self.probe,
                &mut self.bscratch,
                tokens,
                active,
                &mut self.last_logits,
            )?;
            for &slot in &self.bscratch.act {
                out[slot] = self.bscratch.out[slot];
                self.cache.advance_pos(slot, 1);
            }
        }
        self.sample_kv_live();
        Ok(())
    }

    /// The pre-batching decode loop — each active slot stepped on its own
    /// through `forward_token` + `lm_head` — kept verbatim as the bitwise
    /// oracle for the batched path.
    pub fn decode_step_sequential(
        &mut self,
        tokens: &[i32],
        active: &[bool],
        out: &mut [i32],
    ) -> Result<()> {
        anyhow::ensure!(
            tokens.len() == self.batch && active.len() == self.batch && out.len() == self.batch
        );
        for b in 0..self.batch {
            if !active[b] {
                continue;
            }
            forward_token(
                &self.cfg,
                &self.specs,
                &self.weights,
                self.cache.as_mut(),
                &self.pool,
                &self.profiler,
                &mut self.probe,
                &mut self.scratch,
                b,
                tokens[b],
            )?;
            let t_head = self.profiler.start();
            {
                let Scratch { x, head_h, .. } = &mut self.scratch;
                out[b] = lm_head(
                    &self.cfg,
                    &self.weights,
                    &self.pool,
                    x,
                    head_h,
                    &mut self.last_logits[b],
                )?;
            }
            self.profiler.stop(self.cfg.n_layers, Phase::LmHead, t_head);
            self.cache.advance_pos(b, 1);
        }
        Ok(())
    }

    /// Route decode steps through the sequential per-slot loop instead of
    /// the batched kernels (the differential harness's oracle arm).
    pub fn set_sequential_decode(&mut self, on: bool) {
        self.sequential_decode = on;
    }

    /// Feed the profiler's per-layer live-KV-byte peaks from the cache's
    /// current occupancy. Runs after every decode step; the scheduler also
    /// calls it around swap transitions, because a swap-out removes the
    /// victim's bytes from `layer_kv_live` before the next step samples.
    /// With counter tracks attached, the same walk publishes each layer's
    /// live bytes as a time-series point — levels, not just peaks.
    pub fn sample_kv_live(&self) {
        if !self.profiler.enabled() && self.layer_tracks.is_empty() {
            return;
        }
        let live = self.cache.layer_kv_live();
        if self.profiler.enabled() {
            // per-layer live KV bytes (peaks kept)
            for (l, bytes) in live.iter().enumerate() {
                self.profiler.note_kv_live(l, *bytes as u64);
            }
        }
        for (h, bytes) in self.layer_tracks.iter().zip(&live) {
            h.record(*bytes as f64);
        }
    }

    /// Prefill a slot in KIVI-group-sized row blocks (kivi groups commit at
    /// the same boundaries as the token-by-token path, so later prompt
    /// tokens attend over already-quantized earlier ones — identical
    /// numerics, ~group× fewer weight passes). Positions that are not
    /// group-aligned, and tails shorter than a group, fall back to
    /// token-by-token. Returns the first generated token.
    pub fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<i32> {
        self.prefill_extend(slot, prompt)?;
        let t_head = self.profiler.start();
        let out = {
            let Scratch { x, head_h, .. } = &mut self.scratch;
            lm_head(&self.cfg, &self.weights, &self.pool, x, head_h, &mut self.last_logits[slot])
        };
        self.profiler.stop(self.cfg.n_layers, Phase::LmHead, t_head);
        out
    }

    /// Advance `slot` through a chunk of prompt tokens without running the
    /// lm head — the chunked-prefill step. The body of `prefill` minus the
    /// head: group-aligned stretches take the block path, ragged edges fall
    /// back to token-by-token, so splitting a prompt at *any* chunk
    /// boundary leaves the KV state bit-identical to one monolithic prefill
    /// (block-vs-tokenwise parity is pinned by `tests/native_backend.rs`).
    pub fn prefill_extend(&mut self, slot: usize, tokens: &[i32]) -> Result<()> {
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        anyhow::ensure!(
            (self.cache.pos(slot) as usize + tokens.len()) <= self.s_max,
            "prompt overflows cache"
        );
        let g = self.cfg.group;
        // a fresh occupant's rows must not splice onto the previous one's
        // partial probe groups
        if self.cache.pos(slot) == 0 {
            self.probe.reset_slot(slot);
        }
        // the block path parks a whole group in the fp residual ring before
        // committing, so it needs ring capacity >= group
        let block_ok = g >= 1 && self.cfg.residual >= g;
        let mut i = 0usize;
        while i < tokens.len() {
            let pos = self.cache.pos(slot) as usize;
            if block_ok && pos % g == 0 && tokens.len() - i >= g {
                prefill_block(
                    &self.cfg,
                    &self.specs,
                    &self.weights,
                    self.cache.as_mut(),
                    &self.pool,
                    &self.profiler,
                    &mut self.probe,
                    &mut self.scratch,
                    slot,
                    &tokens[i..i + g],
                )?;
                self.cache.advance_pos(slot, g);
                i += g;
            } else {
                forward_token(
                    &self.cfg,
                    &self.specs,
                    &self.weights,
                    self.cache.as_mut(),
                    &self.pool,
                    &self.profiler,
                    &mut self.probe,
                    &mut self.scratch,
                    slot,
                    tokens[i],
                )?;
                self.cache.advance_pos(slot, 1);
                i += 1;
            }
        }
        Ok(())
    }

    /// Token-by-token prefill — the original scalar path, kept as the
    /// parity oracle for `prefill` (the two are asserted bit-identical in
    /// `tests/native_backend.rs` and `table11_native_mt`).
    pub fn prefill_tokenwise(&mut self, slot: usize, prompt: &[i32]) -> Result<i32> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            (self.cache.pos(slot) as usize + prompt.len()) <= self.s_max,
            "prompt overflows cache"
        );
        if self.cache.pos(slot) == 0 {
            self.probe.reset_slot(slot);
        }
        for &t in prompt {
            forward_token(
                &self.cfg,
                &self.specs,
                &self.weights,
                self.cache.as_mut(),
                &self.pool,
                &self.profiler,
                &mut self.probe,
                &mut self.scratch,
                slot,
                t,
            )?;
            self.cache.advance_pos(slot, 1);
        }
        let t_head = self.profiler.start();
        let out = {
            let Scratch { x, head_h, .. } = &mut self.scratch;
            lm_head(&self.cfg, &self.weights, &self.pool, x, head_h, &mut self.last_logits[slot])
        };
        self.profiler.stop(self.cfg.n_layers, Phase::LmHead, t_head);
        out
    }

    /// Greedy generation for one slot (prefill + decode).
    pub fn generate(&mut self, slot: usize, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        self.cache.reset_slot(slot);
        let mut next = self.prefill(slot, prompt)?;
        let mut out = Vec::with_capacity(max_new);
        let mut tokens = vec![0i32; self.batch];
        let mut active = vec![false; self.batch];
        active[slot] = true;
        for _ in 0..max_new {
            out.push(next);
            if self.cache.pos(slot) as usize >= self.s_max {
                break;
            }
            tokens[slot] = next;
            next = self.decode_step(&tokens, &active)?[slot];
        }
        Ok(out)
    }

    pub fn kv_bytes(&self) -> usize {
        self.cache.kv_bytes()
    }

    pub fn equivalent_bits(&self) -> f64 {
        self.cache.equivalent_bits()
    }
}

impl super::EngineCore for NativeEngine {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn s_max(&self) -> usize {
        self.s_max
    }

    fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    fn cache(&self) -> &dyn CacheBackend {
        self.cache.as_ref()
    }

    fn cache_mut(&mut self) -> &mut dyn CacheBackend {
        self.cache.as_mut()
    }

    fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<i32> {
        NativeEngine::prefill(self, slot, prompt)
    }

    fn prefill_extend(&mut self, slot: usize, tokens: &[i32]) -> Result<()> {
        NativeEngine::prefill_extend(self, slot, tokens)
    }

    fn decode_step(&mut self, tokens: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        NativeEngine::decode_step(self, tokens, active)
    }

    fn decode_step_into(&mut self, tokens: &[i32], active: &[bool], out: &mut [i32]) -> Result<()> {
        NativeEngine::decode_step_into(self, tokens, active, out)
    }

    fn logits(&self, slot: usize) -> &[f32] {
        &self.last_logits[slot]
    }

    fn generate(&mut self, slot: usize, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        NativeEngine::generate(self, slot, prompt, max_new)
    }

    fn set_profiling(&mut self, on: bool) {
        self.profiler = if on {
            Profiler::new(
                self.specs
                    .iter()
                    .map(|s| format!("{} K{}V{}", s.mode.as_str(), s.pair.k_bits, s.pair.v_bits))
                    .collect(),
            )
        } else {
            Profiler::disabled()
        };
    }

    fn profile(&self) -> Option<ProfileSnapshot> {
        self.profiler.snapshot()
    }

    fn set_probe(&mut self, cfg: ProbeConfig) {
        self.probe = SensitivityProbe::new(&self.cfg, &self.specs, self.batch, &cfg, false);
    }

    fn sensitivity(&self) -> Option<crate::obs::SensitivitySnapshot> {
        self.probe.snapshot()
    }

    fn sensitivity_shared(&self) -> Option<std::sync::Arc<crate::obs::SensitivityShared>> {
        self.probe.shared()
    }

    fn drift_alerts(&self) -> u64 {
        self.probe.drift_alerts()
    }

    fn sample_kv_live(&self) {
        NativeEngine::sample_kv_live(self)
    }

    fn set_counters(&mut self, counters: &std::sync::Arc<crate::obs::Counters>) {
        self.layer_tracks = self
            .specs
            .iter()
            .enumerate()
            .map(|(l, s)| {
                counters.gauge_with(
                    "layer_kv_live",
                    vec![
                        ("layer".to_string(), format!("{l:02}")),
                        (
                            "spec".to_string(),
                            format!("{} K{}V{}", s.mode.as_str(), s.pair.k_bits, s.pair.v_bits),
                        ),
                    ],
                    "bytes",
                    "live quantized KV bytes resident per layer and precision",
                )
            })
            .collect();
    }
}
