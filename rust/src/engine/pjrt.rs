//! The PJRT inference engine (the `xla` backend): composes one layer-step
//! executable per transformer layer, picked by that layer's precision pair —
//! the paper's layer-wise mixed-precision serving path with zero online
//! decision overhead (the "decision" is an array index resolved at engine
//! build).
//!
//! Python never appears here: artifacts were AOT-lowered by `make artifacts`
//! and are loaded as HLO text through `runtime::Runtime`. On the paged cache
//! arm every layer step gathers live pages into the dense artifact layout
//! first; that staging traffic is counted in `gather_bytes` (it is exactly
//! what the native backend's block-direct kernel eliminates).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};
use xla::Literal;

use crate::config::{LayerSpec, Manifest, Mode, ModelConfig};
use crate::kvcache::{CacheBackend, KvCache, PagedKvCache, PagedOptions};
use crate::model::Weights;
use crate::obs::{CounterHandle, Phase, ProbeConfig, ProfileSnapshot, Profiler, SensitivityProbe};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub struct Engine {
    pub rt: Arc<Runtime>,
    pub cfg: ModelConfig,
    pub specs: Vec<LayerSpec>,
    /// Dense reference arm or the paged block-pool arm, behind one interface.
    pub cache: Box<dyn CacheBackend>,
    pub batch: usize,
    pub s_max: usize,
    pub prefill_chunk: usize,
    /// Logits of the last step per slot (for perplexity / eval paths).
    pub last_logits: Vec<Vec<f32>>,

    weight_lits: Vec<Vec<Literal>>, // [layer][8]
    embed_lit: Literal,
    ln_f_lit: Literal,
    layer_decode: Vec<String>,
    layer_prefill: Vec<String>,
    embed_decode: String,
    embed_prefill: String,
    lmhead_decode: String,
    lmhead_prefill: String,
    /// Per-step executable invocations (for perf accounting).
    pub exec_count: AtomicU64,
    /// Cumulative bytes moved by gather-to-dense staging copies (paged arm
    /// only; the dense arm's buffers already are the artifact layout).
    pub gather_bytes: AtomicU64,
    /// Per-layer phase timings; disabled (and cost-free) unless
    /// `set_profiling(true)`. The XLA backend can't see inside a layer-step
    /// executable, so layer time lands in `Phase::Exec`; host-side cache
    /// routing and kivi quantize executables land in `Phase::QuantCommit`.
    profiler: Profiler,
    /// Online sensitivity probe; disabled by default. The XLA arm cannot
    /// see Q inside its compiled executables, so it runs the probe kv-only:
    /// fp residual chunks are shadowed at kivi commit (`e_k`/`e_v`; the
    /// attention-divergence columns stay zero).
    probe: SensitivityProbe,
    /// One `layer_kv_live{layer,spec}` counter track per layer, attached
    /// via `set_counters`; empty (publication-free) by default.
    layer_tracks: Vec<CounterHandle>,
}

impl Engine {
    /// Build an engine for `model` with one `LayerSpec` per layer, on the
    /// dense (reference) cache arm. `batch` and `s_max` must match emitted
    /// artifact buckets.
    pub fn new(
        rt: Arc<Runtime>,
        model: &str,
        specs: Vec<LayerSpec>,
        batch: usize,
        s_max: usize,
        prefill_chunk: usize,
    ) -> Result<Engine> {
        Engine::build(rt, model, specs, batch, s_max, prefill_chunk, None)
    }

    /// Build an engine on the paged cache arm: same artifacts, same layer
    /// steps, but KV state lives in a block pool sized by `opts` — the
    /// scheduler can then run more slots than the pool could hold at full
    /// length, preempting on page pressure.
    pub fn new_paged(
        rt: Arc<Runtime>,
        model: &str,
        specs: Vec<LayerSpec>,
        batch: usize,
        s_max: usize,
        prefill_chunk: usize,
        opts: PagedOptions,
    ) -> Result<Engine> {
        Engine::build(rt, model, specs, batch, s_max, prefill_chunk, Some(opts))
    }

    fn build(
        rt: Arc<Runtime>,
        model: &str,
        specs: Vec<LayerSpec>,
        batch: usize,
        s_max: usize,
        prefill_chunk: usize,
        paged: Option<PagedOptions>,
    ) -> Result<Engine> {
        let cfg = rt.manifest.config.clone();
        anyhow::ensure!(specs.len() == cfg.n_layers, "one spec per layer");
        let weights = Weights::load(&rt.manifest, model)?;
        weights.validate(&cfg)?;

        let mut weight_lits = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let lits = weights
                .layer(l)?
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<Vec<_>>>()?;
            weight_lits.push(lits);
        }
        let embed_lit = weights.embed()?.to_literal()?;
        let ln_f_lit = weights.ln_f()?.to_literal()?;

        let layer_decode: Vec<String> = specs
            .iter()
            .map(|sp| Manifest::layer_name(sp.mode, sp.pair, batch, 1, s_max))
            .collect();
        let layer_prefill: Vec<String> = specs
            .iter()
            .map(|sp| Manifest::layer_name(sp.mode, sp.pair, 1, prefill_chunk, s_max))
            .collect();
        let embed_decode = format!("embed_b{batch}_t1");
        let embed_prefill = format!("embed_b1_t{prefill_chunk}");
        let lmhead_decode = format!("lmhead_b{batch}");
        let lmhead_prefill = "lmhead_b1".to_string();

        // fail fast if any bucket is missing, and pre-compile everything so
        // the serving path never compiles
        let mut names: Vec<String> = Vec::new();
        names.extend(layer_decode.iter().cloned());
        names.extend(layer_prefill.iter().cloned());
        names.push(embed_decode.clone());
        names.push(embed_prefill.clone());
        names.push(lmhead_decode.clone());
        names.push(lmhead_prefill.clone());
        for sp in &specs {
            if sp.mode == Mode::Kivi {
                names.push(Manifest::quant_name(true, sp.pair.k_bits, 1, cfg.group));
                names.push(Manifest::quant_name(false, sp.pair.v_bits, 1, cfg.group));
            }
        }
        names.sort();
        names.dedup();
        for n in &names {
            rt.manifest.artifact(n).context("engine bucket check")?;
        }
        rt.warmup(&names)?;

        let cache: Box<dyn CacheBackend> = match paged {
            None => Box::new(KvCache::new(&cfg, &specs, batch, s_max)?),
            Some(opts) => Box::new(PagedKvCache::new(&cfg, &specs, batch, s_max, &opts)?),
        };
        Ok(Engine {
            rt,
            cfg,
            specs,
            cache,
            batch,
            s_max,
            prefill_chunk,
            last_logits: vec![Vec::new(); batch],
            weight_lits,
            embed_lit,
            ln_f_lit,
            layer_decode,
            layer_prefill,
            embed_decode,
            embed_prefill,
            lmhead_decode,
            lmhead_prefill,
            exec_count: AtomicU64::new(0),
            gather_bytes: AtomicU64::new(0),
            profiler: Profiler::disabled(),
            probe: SensitivityProbe::disabled(),
            layer_tracks: Vec::new(),
        })
    }

    fn exec(&self, name: &str, inputs: Vec<&Literal>) -> Result<Vec<Tensor>> {
        self.exec_lits(name, inputs)?.iter().map(Tensor::from_literal).collect()
    }

    /// Execute returning raw literals (hot path: avoids Tensor round-trips
    /// for outputs that feed straight into the next executable — §Perf L3-1).
    fn exec_lits(&self, name: &str, inputs: Vec<&Literal>) -> Result<Vec<Literal>> {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        let exe = self.rt.executable(name)?;
        let result = exe.execute::<&Literal>(&inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Run one transformer layer over `x` for slots starting at `slot0`
    /// covering `b_exec` slots, updating the cache with the new tokens.
    /// Returns the layer's hidden output.
    fn run_layer(
        &mut self,
        l: usize,
        artifact: &str,
        x_lit: &Literal,
        slot0: usize,
        b_exec: usize,
        valid: &[usize],
    ) -> Result<Literal> {
        let spec = self.specs[l];
        let single = b_exec == 1 && self.batch != 1;

        let pos: Vec<i32> = (0..b_exec).map(|i| self.cache.pos(slot0 + i)).collect();
        let cache_len: Vec<i32> =
            (0..b_exec).map(|i| self.cache.cache_len(l, slot0 + i)).collect();
        let res_len: Vec<i32> = (0..b_exec).map(|i| self.cache.res_len(l, slot0 + i)).collect();
        let pos_lit = Tensor::i32(&[b_exec], pos).to_literal()?;
        let clen_lit = Tensor::i32(&[b_exec], cache_len).to_literal()?;
        let rlen_lit = Tensor::i32(&[b_exec], res_len).to_literal()?;

        // cache tensors in the artifact layout: whole buffers for full-batch
        // exec, one slot's region for B=1 (the paged arm gathers its pages
        // into the same shapes, so artifacts never see the difference)
        let cache_lits: Vec<Literal> = if single {
            self.cache.slot_literals(l, slot0)?
        } else {
            self.cache.layer_literals(l)?
        };
        if self.cache.is_paged() {
            let n_slots = if single { 1 } else { self.batch };
            self.gather_bytes
                .fetch_add(self.cache.staged_bytes(l, n_slots) as u64, Ordering::Relaxed);
        }

        let mut inputs: Vec<&Literal> = vec![x_lit, &pos_lit, &clen_lit];
        if spec.mode == Mode::Kivi {
            inputs.push(&rlen_lit);
        }
        for w in &self.weight_lits[l] {
            inputs.push(w);
        }
        for c in &cache_lits {
            inputs.push(c);
        }
        let t_exec = self.profiler.start();
        let mut outs = self.exec_lits(artifact, inputs)?;
        self.profiler.stop(l, Phase::Exec, t_exec);

        // route the new-token outputs into the cache per mode (only those
        // tensors cross back to the host; x stays a Literal — §Perf L3-1)
        let t_quant = self.profiler.start();
        let host: Vec<Tensor> =
            outs[1..].iter().map(Tensor::from_literal).collect::<Result<_>>()?;
        match spec.mode {
            Mode::Fp => self.cache.append_fp(l, slot0, &host[0], &host[1], valid)?,
            Mode::Token => self.cache.append_token_outputs(l, slot0, &host[..6], valid)?,
            Mode::Kivi => {
                let commits = self.cache.append_kivi_residual(l, slot0, &host[0], &host[1], valid)?;
                for (bi, need) in commits.iter().enumerate() {
                    if *need {
                        self.commit_kivi(l, slot0 + bi)?;
                    }
                }
            }
        }
        self.profiler.stop(l, Phase::QuantCommit, t_quant);
        Ok(outs.remove(0))
    }

    fn commit_kivi(&mut self, l: usize, slot: usize) -> Result<()> {
        let spec = self.specs[l];
        let (kchunk, vchunk) = self.cache.residual_chunk(l, slot)?;
        // fp shadow of the group before the quant executables consume it
        // (no-op when the probe is disabled)
        self.probe.record_kv_group(l, slot, kchunk.as_f32()?, vchunk.as_f32()?);
        let g = self.cfg.group;
        let kname = Manifest::quant_name(true, spec.pair.k_bits, 1, g);
        let vname = Manifest::quant_name(false, spec.pair.v_bits, 1, g);
        let klit = kchunk.to_literal()?;
        let vlit = vchunk.to_literal()?;
        let k_outs = self.exec(&kname, vec![&klit])?;
        let v_outs = self.exec(&vname, vec![&vlit])?;
        self.cache.commit_kivi_chunk(l, slot, &k_outs, &v_outs)
    }

    /// One decode step over the whole batch. `tokens[b]` is slot b's input
    /// token; `active[b]` gates cache writes and position advance. Returns
    /// the argmax next token per slot (garbage for inactive slots).
    pub fn decode_step(&mut self, tokens: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        anyhow::ensure!(tokens.len() == self.batch && active.len() == self.batch);
        let valid: Vec<usize> = active.iter().map(|&a| a as usize).collect();

        let ids = Tensor::i32(&[self.batch, 1], tokens.to_vec()).to_literal()?;
        let name = self.embed_decode.clone();
        let mut x = self
            .exec_lits(&name, vec![&ids, &self.embed_lit])?
            .remove(0);

        for l in 0..self.cfg.n_layers {
            let art = self.layer_decode[l].clone();
            let x_in = x;
            x = self.run_layer(l, &art, &x_in, 0, self.batch, &valid)?;
        }

        // lm head over [B, D] ([B,1,D] reshaped in place, no copy semantics)
        let x_lit = x.reshape(&[self.batch as i64, self.cfg.d_model as i64])?;
        let lm = self.lmhead_decode.clone();
        let t_head = self.profiler.start();
        let outs = self.exec(&lm, vec![&x_lit, &self.ln_f_lit, &self.embed_lit])?;
        self.profiler.stop(self.cfg.n_layers, Phase::LmHead, t_head);
        let logits = outs[0].as_f32()?;
        for b in 0..self.batch {
            self.last_logits[b] = logits[b * self.cfg.vocab..(b + 1) * self.cfg.vocab].to_vec();
        }
        for b in 0..self.batch {
            if active[b] {
                self.cache.advance_pos(b, 1);
            }
        }
        self.sample_kv_live();
        Ok(outs[1].as_i32()?.to_vec())
    }

    /// Feed the profiler's per-layer live-KV-byte peaks from the cache's
    /// current occupancy (each decode step; the scheduler also calls it
    /// around swap transitions so eviction-time peaks are captured). With
    /// counter tracks attached, the same walk publishes each layer's live
    /// bytes as a time-series point — levels, not just peaks.
    pub fn sample_kv_live(&self) {
        if !self.profiler.enabled() && self.layer_tracks.is_empty() {
            return;
        }
        let live = self.cache.layer_kv_live();
        if self.profiler.enabled() {
            for (l, bytes) in live.iter().enumerate() {
                self.profiler.note_kv_live(l, *bytes as u64);
            }
        }
        for (h, bytes) in self.layer_tracks.iter().zip(&live) {
            h.record(*bytes as f64);
        }
    }

    /// Prefill a slot with a prompt, chunked at `prefill_chunk` (B=1
    /// executables slice the slot's cache region). Returns the first
    /// generated token. Chunked prefill quantizes each chunk before the next
    /// attends, giving prefill-stage error accumulation (paper App. C).
    pub fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<i32> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            (self.cache.pos(slot) as usize + prompt.len()) <= self.s_max,
            "prompt overflows cache"
        );
        let tc = self.prefill_chunk;
        let mut last_hidden: Option<Vec<f32>> = None;
        for chunk in prompt.chunks(tc) {
            let nv = chunk.len();
            let mut ids = chunk.to_vec();
            ids.resize(tc, 0);
            let ids_lit = Tensor::i32(&[1, tc], ids).to_literal()?;
            let ename = self.embed_prefill.clone();
            let mut x = self
                .exec_lits(&ename, vec![&ids_lit, &self.embed_lit])?
                .remove(0);
            for l in 0..self.cfg.n_layers {
                let art = self.layer_prefill[l].clone();
                let x_in = x;
                x = self.run_layer(l, &art, &x_in, slot, 1, &[nv])?;
            }
            self.cache.advance_pos(slot, nv);
            let xt = Tensor::from_literal(&x)?;
            let xf = xt.as_f32()?;
            let d = self.cfg.d_model;
            last_hidden = Some(xf[(nv - 1) * d..nv * d].to_vec());
        }
        let xb = Tensor::f32(&[1, self.cfg.d_model], last_hidden.unwrap());
        let x_lit = xb.to_literal()?;
        let lm = self.lmhead_prefill.clone();
        let t_head = self.profiler.start();
        let outs = self.exec(&lm, vec![&x_lit, &self.ln_f_lit, &self.embed_lit])?;
        self.profiler.stop(self.cfg.n_layers, Phase::LmHead, t_head);
        self.last_logits[slot] = outs[0].as_f32()?.to_vec();
        Ok(outs[1].as_i32()?[0])
    }

    /// Greedy generation for a single slot (prefill + decode), utility for
    /// eval paths. Uses full-batch decode with only this slot active.
    pub fn generate(&mut self, slot: usize, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        self.cache.reset_slot(slot);
        let mut next = self.prefill(slot, prompt)?;
        let mut out = Vec::with_capacity(max_new);
        let mut tokens = vec![0i32; self.batch];
        let mut active = vec![false; self.batch];
        active[slot] = true;
        for _ in 0..max_new {
            out.push(next);
            if self.cache.pos(slot) as usize >= self.s_max {
                break;
            }
            tokens[slot] = next;
            next = self.decode_step(&tokens, &active)?[slot];
        }
        Ok(out)
    }

    pub fn kv_bytes(&self) -> usize {
        self.cache.kv_bytes()
    }

    pub fn equivalent_bits(&self) -> f64 {
        self.cache.equivalent_bits()
    }
}

impl super::EngineCore for Engine {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn s_max(&self) -> usize {
        self.s_max
    }

    fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    fn cache(&self) -> &dyn crate::kvcache::CacheBackend {
        self.cache.as_ref()
    }

    fn cache_mut(&mut self) -> &mut dyn crate::kvcache::CacheBackend {
        self.cache.as_mut()
    }

    fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<i32> {
        Engine::prefill(self, slot, prompt)
    }

    fn decode_step(&mut self, tokens: &[i32], active: &[bool]) -> Result<Vec<i32>> {
        Engine::decode_step(self, tokens, active)
    }

    fn logits(&self, slot: usize) -> &[f32] {
        &self.last_logits[slot]
    }

    fn gather_bytes(&self) -> u64 {
        self.gather_bytes.load(Ordering::Relaxed)
    }

    fn set_profiling(&mut self, on: bool) {
        self.profiler = if on {
            Profiler::new(
                self.specs
                    .iter()
                    .map(|s| format!("{} K{}V{}", s.mode.as_str(), s.pair.k_bits, s.pair.v_bits))
                    .collect(),
            )
        } else {
            Profiler::disabled()
        };
    }

    fn profile(&self) -> Option<ProfileSnapshot> {
        self.profiler.snapshot()
    }

    fn set_probe(&mut self, cfg: ProbeConfig) {
        self.probe = SensitivityProbe::new(&self.cfg, &self.specs, self.batch, &cfg, true);
    }

    fn sensitivity(&self) -> Option<crate::obs::SensitivitySnapshot> {
        self.probe.snapshot()
    }

    fn sensitivity_shared(&self) -> Option<Arc<crate::obs::SensitivityShared>> {
        self.probe.shared()
    }

    fn drift_alerts(&self) -> u64 {
        self.probe.drift_alerts()
    }

    fn sample_kv_live(&self) {
        Engine::sample_kv_live(self)
    }

    fn set_counters(&mut self, counters: &Arc<crate::obs::Counters>) {
        self.layer_tracks = self
            .specs
            .iter()
            .enumerate()
            .map(|(l, s)| {
                counters.gauge_with(
                    "layer_kv_live",
                    vec![
                        ("layer".to_string(), format!("{l:02}")),
                        (
                            "spec".to_string(),
                            format!("{} K{}V{}", s.mode.as_str(), s.pair.k_bits, s.pair.v_bits),
                        ),
                    ],
                    "bytes",
                    "live quantized KV bytes resident per layer and precision",
                )
            })
            .collect();
    }

    fn generate(&mut self, slot: usize, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        Engine::generate(self, slot, prompt, max_new)
    }
}
