//! Inference engine backends behind one surface (`EngineCore`):
//!
//! * `pjrt::Engine` (feature `xla`, the default) — AOT-lowered PJRT
//!   executables, one layer-step artifact per precision pair. Needs
//!   `make artifacts` and the XLA extension; its paged arm pays a
//!   gather-to-dense staging copy per layer step (counted in
//!   `gather_bytes`).
//! * `native::NativeEngine` — in-process CPU kernels (`crate::kernel`)
//!   that walk the cache's block tables directly and dequantize pages on
//!   read. Zero artifacts, zero staging bytes; runs on hosts without the
//!   XLA extension, which is what lets the engine/router test suite run on
//!   hosted CI.
//!
//! The scheduler, router and CLI only see `EngineCore`, so the two
//! backends are interchangeable behind `--backend {xla,native}`.

pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

pub use native::NativeEngine;
#[cfg(feature = "xla")]
pub use pjrt::Engine;

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::kvcache::CacheBackend;

/// Which engine implementation a worker / CLI run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT executables from AOT artifacts (needs the XLA extension).
    Xla,
    /// Native CPU kernels, block-table-direct (zero artifacts).
    Native,
}

impl Default for BackendKind {
    fn default() -> Self {
        if cfg!(feature = "xla") {
            BackendKind::Xla
        } else {
            BackendKind::Native
        }
    }
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "xla" | "pjrt" => Ok(BackendKind::Xla),
            "native" | "kernel" => Ok(BackendKind::Native),
            other => bail!("unknown backend {other:?} (expected xla|native)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Xla => "xla",
            BackendKind::Native => "native",
        }
    }
}

/// The engine surface the serving stack programs against: prefill + batched
/// decode over a `CacheBackend`, per-slot logits, and perf accounting.
pub trait EngineCore {
    fn cfg(&self) -> &ModelConfig;
    fn batch(&self) -> usize;
    fn s_max(&self) -> usize;
    fn prefill_chunk(&self) -> usize;
    fn cache(&self) -> &dyn CacheBackend;
    fn cache_mut(&mut self) -> &mut dyn CacheBackend;
    /// Prefill a slot with a prompt; returns the first generated token.
    fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<i32>;
    /// Advance a slot's prefill by a chunk of prompt tokens *without*
    /// computing logits — the chunked-prefill step (only the final chunk,
    /// fed to `prefill`, pays the lm head). The default runs a full prefill
    /// and discards the token; engines with a headless path override it.
    fn prefill_extend(&mut self, slot: usize, tokens: &[i32]) -> Result<()> {
        self.prefill(slot, tokens).map(|_| ())
    }
    /// One decode step over the whole batch; `active[b]` gates cache writes.
    /// Returns the argmax next token per slot (garbage for inactive slots).
    fn decode_step(&mut self, tokens: &[i32], active: &[bool]) -> Result<Vec<i32>>;
    /// Allocation-free form of `decode_step`: the caller owns `out` (length
    /// `batch()`), refilled in place. The serving loop's hot path — engines
    /// with a resident output buffer override the defaulted delegation.
    fn decode_step_into(&mut self, tokens: &[i32], active: &[bool], out: &mut [i32]) -> Result<()> {
        let next = self.decode_step(tokens, active)?;
        out.copy_from_slice(&next);
        Ok(())
    }
    /// Logits of the slot's most recent step.
    fn logits(&self, slot: usize) -> &[f32];
    /// Cumulative bytes moved by gather-to-dense staging copies (the XLA
    /// paged arm). The native block-direct path never stages: always 0.
    fn gather_bytes(&self) -> u64 {
        0
    }

    /// Toggle the per-layer/per-phase profiler (`--profile-serve`). The
    /// default engine keeps it off — and pays nothing for it.
    fn set_profiling(&mut self, _on: bool) {}

    /// Accumulated per-layer profile; `None` unless profiling was enabled.
    fn profile(&self) -> Option<crate::obs::ProfileSnapshot> {
        None
    }

    /// Arm the online sensitivity probe (`--probe-every`). The default
    /// engine has no probe — and pays nothing for one.
    fn set_probe(&mut self, _cfg: crate::obs::ProbeConfig) {}

    /// Accumulated online sensitivity; `None` unless a probe is armed.
    fn sensitivity(&self) -> Option<crate::obs::SensitivitySnapshot> {
        None
    }

    /// The probe's live accumulator table, for mid-run streaming readers.
    fn sensitivity_shared(&self) -> Option<std::sync::Arc<crate::obs::SensitivityShared>> {
        None
    }

    /// Cumulative envelope-exceeded drift alerts from the probe.
    fn drift_alerts(&self) -> u64 {
        0
    }

    /// Feed the profiler's per-layer live-KV-byte peaks from the cache's
    /// current occupancy. Engines call this each decode step; the scheduler
    /// also calls it around swap-out/swap-in so eviction-time peaks are
    /// captured (a swapped-out slot's bytes vanish from `layer_kv_live`).
    /// With counters attached (`set_counters`) the same sampling also
    /// publishes per-layer `layer_kv_live` time-series points.
    fn sample_kv_live(&self) {}

    /// Attach a counter registry: `sample_kv_live` additionally publishes
    /// each layer's live KV bytes as a `layer_kv_live{layer,spec}` track.
    /// The default engine publishes nothing — and pays nothing.
    fn set_counters(&mut self, _counters: &std::sync::Arc<crate::obs::Counters>) {}

    fn kv_bytes(&self) -> usize {
        self.cache().kv_bytes()
    }

    fn equivalent_bits(&self) -> f64 {
        self.cache().equivalent_bits()
    }

    /// Greedy generation for a single slot (prefill + decode), utility for
    /// eval paths. Uses full-batch decode with only this slot active.
    fn generate(&mut self, slot: usize, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        self.cache_mut().reset_slot(slot);
        let mut next = self.prefill(slot, prompt)?;
        let (batch, s_max) = (self.batch(), self.s_max());
        let mut out = Vec::with_capacity(max_new);
        let mut tokens = vec![0i32; batch];
        let mut active = vec![false; batch];
        active[slot] = true;
        for _ in 0..max_new {
            out.push(next);
            if self.cache().pos(slot) as usize >= s_max {
                break;
            }
            tokens[slot] = next;
            next = self.decode_step(&tokens, &active)?[slot];
        }
        Ok(out)
    }
}
