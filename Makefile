# Build-time entry points. `make artifacts` is what the Rust-side error
# messages and docs refer to: it AOT-lowers every layer-step / quant / embed /
# lm-head bucket to HLO text under artifacts/ and writes the manifest the
# runtime loads. Python runs only here, never on the serving path.

.PHONY: artifacts verify bench bench-baseline

artifacts:
	cd python && python -m compile.aot

# tier-1 gate (same as CI)
verify:
	cargo build --release && cargo test -q

# paper-table benches that run with or without artifacts
bench:
	cargo bench --bench table8_paged
	cargo bench --bench table9_swap

# artifact-free benches whose BENCH_JSON output seeds the perf baseline
BASELINE_BENCHES = table2_ppl table3_eo table8_throughput table8_paged \
                   table9_swap table10_kernel table11_native_mt

# run the full artifact-free bench suite and collect every BENCH_JSON line
# into the checked-in baseline; python/bench_compare.py compare flags >15%
# regressions against it (advisory in CI)
bench-baseline:
	rm -f bench_baseline.out
	for b in $(BASELINE_BENCHES); do \
		cargo bench --bench $$b --no-default-features | tee -a bench_baseline.out || exit 1; \
	done
	python3 python/bench_compare.py collect bench_baseline.out -o BENCH_BASELINE.json
