# Build-time entry points. `make artifacts` is what the Rust-side error
# messages and docs refer to: it AOT-lowers every layer-step / quant / embed /
# lm-head bucket to HLO text under artifacts/ and writes the manifest the
# runtime loads. Python runs only here, never on the serving path.

.PHONY: artifacts verify bench

artifacts:
	cd python && python -m compile.aot

# tier-1 gate (same as CI)
verify:
	cargo build --release && cargo test -q

# paper-table benches that run with or without artifacts
bench:
	cargo bench --bench table8_paged
	cargo bench --bench table9_swap
