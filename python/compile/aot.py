"""AOT lowering: every layer-step / quantize / embed / lm-head variant to
HLO *text* artifacts, plus weight blobs and a manifest the Rust runtime loads.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are keyed by *shape signature* (config, batch, t, s_max) and shared
by every model (weight set) with the same config shape, since weights are
runtime inputs. ``python -m compile.aot --help`` for the knobs; the default
emits the `tiny` family used by tests plus the `small` family used by benches
only when requested.

Python runs ONCE at build time (`make artifacts`); it is never on the Rust
request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

PAIRS = [(k, v) for k in (8, 4, 2) for v in (8, 4, 2)]
MODES = ("token", "kivi")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(name, spec):
    return {"name": name, "dtype": str(spec.dtype), "shape": list(spec.shape)}


def _out_json(outs):
    flat, _ = jax.tree_util.tree_flatten(outs)
    return [{"dtype": str(o.dtype), "shape": list(o.shape)} for o in flat]


def lower_artifact(fn, specs, out_dir, name, meta, artifacts, force=False):
    """Lower ``fn(*specs)`` to ``<out_dir>/<name>.hlo.txt`` and record it."""
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    shaped = [s for (_, s) in specs]
    lowered = jax.jit(fn).lower(*shaped)
    out_shapes = jax.eval_shape(fn, *shaped)
    if force or not os.path.exists(path):
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
    entry = dict(meta)
    entry.update(
        name=name,
        file=f"{name}.hlo.txt",
        inputs=[_spec_json(n, s) for (n, s) in specs],
        outputs=_out_json(out_shapes),
    )
    artifacts.append(entry)


def emit_weights(cfg: M.ModelConfig, out_dir: str):
    """weights-<model>.bin: flat little-endian f32; returns the tensor index."""
    w = M.init_weights(cfg)
    order = ["embed", "ln_f"] + [
        f"layer{l}.{nm}" for l in range(cfg.n_layers) for nm in M.LAYER_WEIGHT_NAMES
    ]
    tensors, offset = {}, 0
    path = os.path.join(out_dir, f"weights-{cfg.name}.bin")
    with open(path, "wb") as f:
        for nm in order:
            t = np.ascontiguousarray(w[nm], dtype=np.float32)
            f.write(t.tobytes())
            tensors[nm] = {"offset": offset, "shape": list(t.shape)}
            offset += t.size
    outlier, temps, _ = M.sensitivity_profiles(cfg)
    return {
        "weights": f"weights-{cfg.name}.bin",
        "tensors": tensors,
        "outlier_profile": [float(x) for x in outlier],
        "temp_profile": [[float(x) for x in row] for row in temps],
    }


def emit(config: str, models, batches, ts, s_maxes, out_root: str, force: bool):
    cfg = M.CONFIGS[config]
    out_dir = os.path.join(out_root, config)
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []
    t0 = time.time()

    def log(msg):
        print(f"[aot {time.time() - t0:6.1f}s] {msg}", flush=True)

    for b in batches:
        for s in s_maxes:
            for t in ts:
                meta = {"kind": "layer", "batch": b, "t": t, "s_max": s}
                specs = M.layer_step_specs(cfg, "fp", 16, 16, b, t, s)
                lower_artifact(
                    M.make_layer_step(cfg, "fp", 16, 16, b, t, s),
                    specs, out_dir, f"layer_fp_b{b}_t{t}_s{s}",
                    dict(meta, mode="fp", k_bits=16, v_bits=16), artifacts, force,
                )
                log(f"layer fp b{b} t{t} s{s}")
                for mode in MODES:
                    for kb, vb in PAIRS:
                        specs = M.layer_step_specs(cfg, mode, kb, vb, b, t, s)
                        lower_artifact(
                            M.make_layer_step(cfg, mode, kb, vb, b, t, s),
                            specs, out_dir, f"layer_{mode}_k{kb}v{vb}_b{b}_t{t}_s{s}",
                            dict(meta, mode=mode, k_bits=kb, v_bits=vb), artifacts, force,
                        )
                    log(f"layer {mode} b{b} t{t} s{s} (9 pairs)")
        # commit executables (chunk == group) and heads, per batch size
        for bits in (8, 4, 2):
            for mode, mname in (("per-token-asym", "token"), ("per-channel-asym", "channel")):
                c = cfg.group
                specs = [("x", M._f32(b, cfg.n_kv_heads, c, cfg.head_dim))]
                lower_artifact(
                    M.make_quantize_chunk(cfg, bits, mode, b, c),
                    specs, out_dir, f"quant_{mname}_{bits}_b{b}_c{c}",
                    {"kind": "quant", "mode": mname, "bits": bits, "batch": b, "chunk": c},
                    artifacts, force,
                )
        for t in ts:
            specs = [("ids", M._i32(b, t)), ("embed", M._f32(cfg.vocab, cfg.d_model))]
            lower_artifact(
                M.make_embed(cfg, b, t), specs, out_dir, f"embed_b{b}_t{t}",
                {"kind": "embed", "batch": b, "t": t}, artifacts, force,
            )
        specs = [
            ("x", M._f32(b, cfg.d_model)),
            ("ln_f", M._f32(cfg.d_model)),
            ("embed", M._f32(cfg.vocab, cfg.d_model)),
        ]
        lower_artifact(
            M.make_lm_head(cfg, b), specs, out_dir, f"lmhead_b{b}",
            {"kind": "lmhead", "batch": b}, artifacts, force,
        )
        log(f"quant/embed/lmhead b{b}")

    model_entries = {}
    for mn in models:
        mcfg = M.CONFIGS[mn]
        assert M.layer_weight_shapes(mcfg) == M.layer_weight_shapes(cfg), mn
        model_entries[mn] = emit_weights(mcfg, out_dir)
        log(f"weights {mn}")

    manifest = {
        "config": {
            "name": cfg.name, "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim, "d_ff": cfg.d_ff, "vocab": cfg.vocab,
            "rope_theta": cfg.rope_theta, "group": cfg.group,
            "residual": cfg.residual, "rms_eps": cfg.rms_eps,
        },
        "models": model_entries,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"manifest: {len(artifacts)} artifacts -> {out_dir}")


def source_stamp() -> str:
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    for root, _, files in os.walk(base):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--config", default="tiny")
    p.add_argument("--models", default="tiny,tiny-robust,tiny-sensitive")
    p.add_argument("--batch", default="1,2")
    p.add_argument("--t", default="1,32")
    p.add_argument("--smax", default="256")
    p.add_argument("--out", default=None, help="output root (default ../artifacts)")
    p.add_argument("--force", action="store_true")
    args = p.parse_args()
    out_root = args.out or os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    out_root = os.path.abspath(out_root)
    stamp_path = os.path.join(out_root, args.config, ".stamp")
    stamp = source_stamp() + f"|{args.models}|{args.batch}|{args.t}|{args.smax}"
    if not args.force and os.path.exists(stamp_path):
        with open(stamp_path) as f:
            if f.read() == stamp:
                print(f"[aot] {args.config}: up to date, skipping")
                return
    emit(
        args.config,
        args.models.split(","),
        [int(x) for x in args.batch.split(",")],
        [int(x) for x in args.t.split(",")],
        [int(x) for x in args.smax.split(",")],
        out_root,
        True,
    )
    with open(stamp_path, "w") as f:
        f.write(stamp)


if __name__ == "__main__":
    main()
