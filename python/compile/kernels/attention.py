"""Pallas fused attention kernel (flash-style online softmax) for decode.

The kernel computes ``softmax(q K^T / sqrt(Dh) + mask) V`` for grouped-query
attention, streaming over key/value blocks with a running (max, denominator,
accumulator) triple — the TPU-shaped restructuring of the paper's GPU
attention path (DESIGN.md §Hardware-Adaptation): HBM→VMEM streaming of
(head, seq-block) tiles replaces the CUDA threadblock tiling, and the MXU
consumes [T, Dh] × [Dh, BLK] tiles.

K/V arrive already dequantized (the dequant kernel in ``quant.py`` feeds
this one inside the same HLO module, so XLA fuses them on a real backend).
Masking is additive (-inf for invalid positions), which subsumes the
cache-length mask, residual-length mask, and causal mask within the T new
tokens — the model layer builds the mask once per step.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr, *, sm_scale, blocks):
    blk = pl.program_id(2)

    @pl.when(blk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]  # [T, Dh]
    k = k_ref[0, 0]  # [BLK, Dh]
    v = v_ref[0, 0]  # [BLK, Dh]
    mask = mask_ref[0]  # [T, BLK]

    s = jnp.dot(q, k.T) * sm_scale + mask  # [T, BLK]
    m_prev = m_scr[...]  # [T, 1]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)  # [T, BLK]
    alpha = jnp.exp(m_prev - m_new)  # [T, 1]
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(blk == blocks - 1)
    def _finish():
        o_ref[0, 0] = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)


def _fused_attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, sm_scale, group):
    """Whole-sequence, all-heads attention for one batch element.

    Grid is (B,) only: on the CPU interpret path every grid step lowers to a
    sequential loop iteration, so folding heads + seq blocks into one kernel
    invocation is the dominant perf lever (§Perf change L1-1). On a real TPU
    this trades VMEM residency for loop overhead — the [S, Dh] K/V tiles at
    S=1024, Dh=64 are 256 KiB each, still comfortably inside VMEM.
    """
    q = q_ref[0]  # [Hq, T, Dh]
    k = k_ref[0]  # [Hkv, S, Dh]
    v = v_ref[0]
    mask = mask_ref[0]  # [T, S]
    hq = q.shape[0]
    # GQA: repeat kv heads across the query-head group
    kx = jnp.repeat(k, group, axis=0)  # [Hq, S, Dh]
    vx = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("htd,hsd->hts", q, kx) * sm_scale + mask[None]
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    del hq
    o_ref[0] = jnp.einsum("hts,hsd->htd", p, vx)


def fused_attention(q, k, v, mask):
    """Single-block attention with grid (B,) — see _fused_attn_kernel."""
    b, hq, t, dh = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    kernel = functools.partial(
        _fused_attn_kernel, sm_scale=1.0 / math.sqrt(dh), group=group
    )
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, hq, t, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, hkv, s, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, hkv, s, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, s), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, t, dh), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, t, dh), jnp.float32),
        interpret=True,
    )(q, k, v, mask)


def flash_attention(q, k, v, mask, *, block_k: int = 128):
    """Grouped-query flash attention over a static-length KV buffer.

    q: [B, Hq, T, Dh]; k/v: [B, Hkv, S, Dh]; mask: [B, T, S] additive fp32.
    Hq must be a multiple of Hkv (GQA). Returns [B, Hq, T, Dh].
    """
    b, hq, t, dh = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0, f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}"
    group = hq // hkv
    blk = min(block_k, s)
    assert s % blk == 0, f"S={s} must be a multiple of block_k={blk}"
    blocks = s // blk
    sm_scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(_attn_kernel, sm_scale=sm_scale, blocks=blocks)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, blocks),
        in_specs=[
            pl.BlockSpec((1, 1, t, dh), lambda i, j, g: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, blk, dh), lambda i, j, g: (i, j // group, g, 0)),
            pl.BlockSpec((1, 1, blk, dh), lambda i, j, g: (i, j // group, g, 0)),
            pl.BlockSpec((1, t, blk), lambda i, j, g: (i, 0, g)),
        ],
        out_specs=pl.BlockSpec((1, 1, t, dh), lambda i, j, g: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, t, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((t, 1), jnp.float32),
            pltpu.VMEM((t, 1), jnp.float32),
            pltpu.VMEM((t, dh), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, mask)
