"""Bit-packing helpers for low-precision KV cache codes.

Codes are packed along the *head dimension* (the last axis) into uint8 bytes:
with ``bits`` in {2, 4, 8}, ``per_byte = 8 // bits`` consecutive channels share
one byte, channel ``d`` occupying bit positions ``bits * (d % per_byte)``.

Packing along the head dim (rather than the sequence dim) keeps the unpack a
pure lane-local shift on TPU (the Pallas analogue of avoiding cross-warp
shuffles in the KIVI CUDA kernels): a VMEM tile of packed codes expands to the
fp tile in-register, with no cross-lane data movement.

These helpers are pure ``jnp`` so they can be used both inside Pallas kernels
(interpret mode) and in the ``ref.py`` oracles.
"""

from __future__ import annotations

import jax.numpy as jnp

SUPPORTED_BITS = (2, 4, 8)


def packed_width(head_dim: int, bits: int) -> int:
    """Number of bytes needed to pack ``head_dim`` codes at ``bits`` each."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {bits}")
    if head_dim * bits % 8 != 0:
        raise ValueError(f"head_dim={head_dim} not packable at {bits} bits")
    return head_dim * bits // 8


def pack_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack integer codes in [0, 2^bits) along the last axis into uint8.

    codes: [..., Dh] integer array with values < 2**bits.
    returns: [..., Dh * bits // 8] uint8.
    """
    if bits == 8:
        return codes.astype(jnp.uint8)
    per_byte = 8 // bits
    dh = codes.shape[-1]
    grouped = codes.astype(jnp.uint32).reshape(*codes.shape[:-1], dh // per_byte, per_byte)
    shifts = (jnp.arange(per_byte, dtype=jnp.uint32) * bits).reshape(
        (1,) * (grouped.ndim - 1) + (per_byte,)
    )
    packed = jnp.sum(grouped << shifts, axis=-1)
    return packed.astype(jnp.uint8)


def unpack_codes(packed: jnp.ndarray, bits: int, head_dim: int) -> jnp.ndarray:
    """Inverse of :func:`pack_codes`.

    packed: [..., Dh * bits // 8] uint8.
    returns: [..., Dh] uint8 codes in [0, 2^bits).
    """
    if bits == 8:
        return packed
    per_byte = 8 // bits
    mask = jnp.uint32((1 << bits) - 1)
    expanded = packed.astype(jnp.uint32)[..., :, None]  # [..., DhP, 1]
    shifts = (jnp.arange(per_byte, dtype=jnp.uint32) * bits).reshape(
        (1,) * (expanded.ndim - 1) + (per_byte,)
    )
    codes = (expanded >> shifts) & mask
    return codes.reshape(*packed.shape[:-1], head_dim).astype(jnp.uint8)
