"""Pallas kernels for asymmetric KV cache quantization (L1 hot-spot).

Two quantization modes, matching the paper (Sec. 3.2 / 4.2):

* ``per-token-asym`` — one (scale, zero) per (batch, head, token), computed
  over the head_dim channels of that token. Used for values always, and for
  keys in the plain per-token baseline.
* ``per-channel-asym`` — one (scale, zero) per (batch, head, channel),
  computed over a *token group* of G tokens (KIVI-style key quantization;
  the paper uses G = 32 with a fp residual of 32 recent tokens).

All kernels run under ``interpret=True``: real-TPU Mosaic lowering emits a
custom-call the CPU PJRT plugin cannot execute. Block shapes are still chosen
for the TPU mapping documented in DESIGN.md §Hardware-Adaptation: one
(batch, head) grid cell owns a [G, Dh] VMEM tile; pack/unpack is a lane-local
shift; scales live in VMEM for the whole tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .packing import pack_codes, packed_width, unpack_codes

_EPS = 1e-8


# ---------------------------------------------------------------------------
# Quantize: fp chunk [B, H, G, Dh] -> packed codes + scale/zero
# ---------------------------------------------------------------------------


def _quantize_token_kernel(x_ref, codes_ref, scale_ref, zero_ref, *, bits):
    x = x_ref[0, 0]  # [G, Dh]
    qmax = float(2**bits - 1)
    lo = jnp.min(x, axis=-1, keepdims=True)  # [G, 1]
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, _EPS)
    codes = jnp.clip(jnp.round((x - lo) / scale), 0.0, qmax).astype(jnp.uint8)
    codes_ref[0, 0] = pack_codes(codes, bits)
    scale_ref[0, 0] = scale[:, 0]
    zero_ref[0, 0] = lo[:, 0]


def _quantize_channel_kernel(x_ref, codes_ref, scale_ref, zero_ref, *, bits):
    x = x_ref[0, 0]  # [G, Dh]
    qmax = float(2**bits - 1)
    lo = jnp.min(x, axis=0, keepdims=True)  # [1, Dh]
    hi = jnp.max(x, axis=0, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, _EPS)
    codes = jnp.clip(jnp.round((x - lo) / scale), 0.0, qmax).astype(jnp.uint8)
    codes_ref[0, 0] = pack_codes(codes, bits)
    scale_ref[0, 0] = scale[0]
    zero_ref[0, 0] = lo[0]


def quantize_chunk(x: jnp.ndarray, bits: int, mode: str):
    """Quantize a fp chunk of KV cache.

    x: [B, H, G, Dh] float32.
    Returns (codes [B,H,G,DhP] u8, scale, zero) where scale/zero are
    [B,H,G] for per-token mode and [B,H,Dh] for per-channel mode.
    """
    b, h, g, dh = x.shape
    dhp = packed_width(dh, bits)
    if mode == "per-token-asym":
        kernel = functools.partial(_quantize_token_kernel, bits=bits)
        sz_shape, sz_block = (b, h, g), (1, 1, g)
    elif mode == "per-channel-asym":
        kernel = functools.partial(_quantize_channel_kernel, bits=bits)
        sz_shape, sz_block = (b, h, dh), (1, 1, dh)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[pl.BlockSpec((1, 1, g, dh), lambda i, j: (i, j, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, g, dhp), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec(sz_block, lambda i, j: (i, j, 0)),
            pl.BlockSpec(sz_block, lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, g, dhp), jnp.uint8),
            jax.ShapeDtypeStruct(sz_shape, jnp.float32),
            jax.ShapeDtypeStruct(sz_shape, jnp.float32),
        ],
        interpret=True,
    )(x)


# ---------------------------------------------------------------------------
# Dequantize: packed codes + scale/zero -> fp [B, H, S, Dh]
# ---------------------------------------------------------------------------


def _dequantize_token_kernel(codes_ref, scale_ref, zero_ref, out_ref, *, bits, dh):
    codes = unpack_codes(codes_ref[0, 0], bits, dh).astype(jnp.float32)  # [S, Dh]
    out_ref[0, 0] = codes * scale_ref[0, 0][:, None] + zero_ref[0, 0][:, None]


def _dequantize_channel_kernel(codes_ref, scale_ref, zero_ref, out_ref, *, bits, dh, group):
    codes = unpack_codes(codes_ref[0, 0], bits, dh).astype(jnp.float32)  # [S, Dh]
    s = codes.shape[0]
    # scale/zero: [S/G, Dh] -> broadcast each row over its token group.
    scale = jnp.repeat(scale_ref[0, 0], group, axis=0)
    zero = jnp.repeat(zero_ref[0, 0], group, axis=0)
    out_ref[0, 0] = codes * scale[:s] + zero[:s]


def dequantize(codes, scale, zero, bits: int, mode: str, head_dim: int, group: int = 32):
    """Dequantize packed KV cache codes.

    codes: [B, H, S, DhP] u8. scale/zero: [B,H,S] (per-token) or
    [B,H,S//G,Dh] (per-channel, one row per committed token group).
    Returns fp32 [B, H, S, Dh].
    """
    b, h, s, dhp = codes.shape
    assert dhp == packed_width(head_dim, bits)
    if mode == "per-token-asym":
        kernel = functools.partial(_dequantize_token_kernel, bits=bits, dh=head_dim)
        sz_block = pl.BlockSpec((1, 1, s), lambda i, j: (i, j, 0))
    elif mode == "per-channel-asym":
        kernel = functools.partial(
            _dequantize_channel_kernel, bits=bits, dh=head_dim, group=group
        )
        ng = scale.shape[2]
        sz_block = pl.BlockSpec((1, 1, ng, head_dim), lambda i, j: (i, j, 0, 0))
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, s, dhp), lambda i, j: (i, j, 0, 0)),
            sz_block,
            sz_block,
        ],
        out_specs=pl.BlockSpec((1, 1, s, head_dim), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, head_dim), jnp.float32),
        interpret=True,
    )(codes, scale, zero)
