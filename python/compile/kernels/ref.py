"""Pure-jnp oracles for every Pallas kernel (the build-time correctness signal).

Each function here mirrors one kernel in ``quant.py`` / ``attention.py`` with
straight-line jnp — no pallas, no packing tricks beyond the shared helpers.
pytest asserts allclose between kernel and oracle across shapes/dtypes/modes
(see python/tests/).
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from .packing import pack_codes, unpack_codes

_EPS = 1e-8


def quantize_chunk_ref(x, bits: int, mode: str):
    """Oracle for quant.quantize_chunk. x: [B, H, G, Dh]."""
    qmax = float(2**bits - 1)
    if mode == "per-token-asym":
        lo = jnp.min(x, axis=-1, keepdims=True)  # [B,H,G,1]
        hi = jnp.max(x, axis=-1, keepdims=True)
    elif mode == "per-channel-asym":
        lo = jnp.min(x, axis=2, keepdims=True)  # [B,H,1,Dh]
        hi = jnp.max(x, axis=2, keepdims=True)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    scale = jnp.maximum((hi - lo) / qmax, _EPS)
    codes = jnp.clip(jnp.round((x - lo) / scale), 0.0, qmax).astype(jnp.uint8)
    packed = pack_codes(codes, bits)
    if mode == "per-token-asym":
        return packed, scale[..., 0], lo[..., 0]
    return packed, scale[:, :, 0, :], lo[:, :, 0, :]


def dequantize_ref(codes, scale, zero, bits: int, mode: str, head_dim: int, group: int = 32):
    """Oracle for quant.dequantize. codes: [B, H, S, DhP]."""
    x = unpack_codes(codes, bits, head_dim).astype(jnp.float32)  # [B,H,S,Dh]
    if mode == "per-token-asym":
        return x * scale[..., None] + zero[..., None]
    if mode == "per-channel-asym":
        s = codes.shape[2]
        sc = jnp.repeat(scale, group, axis=2)[:, :, :s, :]
        zp = jnp.repeat(zero, group, axis=2)[:, :, :s, :]
        return x * sc + zp
    raise ValueError(f"unknown mode {mode!r}")


def fake_quant_ref(x, bits: int, mode: str, group: int | None = None):
    """Quantize + dequantize round trip (offline error-profiling primitive).

    The whole chunk is one quantization group: per-channel stats span all
    of x's token axis (pass ``group`` only to mimic a multi-group cache).
    """
    codes, scale, zero = quantize_chunk_ref(x, bits, mode)
    if mode == "per-channel-asym":
        scale, zero = scale[:, :, None, :], zero[:, :, None, :]
        group = group or x.shape[2]
    return dequantize_ref(codes, scale, zero, bits, mode, x.shape[-1], group or 32)


def attention_ref(q, k, v, mask):
    """Oracle for attention.flash_attention (naive two-pass softmax).

    q: [B, Hq, T, Dh]; k/v: [B, Hkv, S, Dh]; mask: [B, T, S].
    """
    b, hq, t, dh = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)  # [B, Hq, S, Dh]
    vx = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, kx) / math.sqrt(dh)
    scores = scores + mask[:, None, :, :]
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhts,bhsd->bhtd", probs, vx)
