"""L2: the transformer decode/prefill graph, calling the L1 Pallas kernels.

This module defines, per (quant-mode, k-bits, v-bits), the *single transformer
layer step* that the Rust coordinator composes L times per token. The layer
step is what gets AOT-lowered to HLO text (see ``aot.py``); the per-layer
precision pair is baked into which executable Rust picks — the paper's
"zero online decision overhead" property reduces to an array index.

Quant modes (paper Sec. 3.2/4.2, App. C):

* ``token`` — per-token-asym for both K and V. New-token K/V are quantized
  *inside* the layer step (outputs are packed codes), so the per-token
  baseline has no fp residual, matching the paper.
* ``kivi``  — key per-channel-asym (token groups of G=32) + value
  per-token-asym, both with a fp residual window of R=32 recent tokens.
  The layer step outputs fp new-token K/V; the Rust cache manager owns the
  residual ring and calls the ``quantize_chunk`` executables at commit time.
* ``fp``    — full-precision cache (the 16-bit reference arm of
  ``f_a(P) = A(KV_half) - A(KV_P)``).

Synthetic-model substitution (DESIGN.md §2): weights are random but with
*engineered* per-layer key-channel outliers and per-head attention sharpness,
so the layer-wise sensitivity landscape is heterogeneous the way Fig. 7 / 11 /
12 of the paper show for real LLMs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import flash_attention, fused_attention
from .kernels.packing import packed_width
from .kernels.quant import dequantize, quantize_chunk

ATTN_BLOCK = 64  # flash-attention seq block; totals are padded to a multiple
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    group: int = 32       # per-channel token group == residual commit size
    residual: int = 32    # fp residual window (KIVI residual length)
    rms_eps: float = 1e-5
    seed: int = 0
    outlier_max: float = 16.0   # max per-layer key-channel outlier magnitude
    temp_max: float = 3.0       # max per-head qk sharpness multiplier

    def __post_init__(self):
        assert self.n_heads % self.n_kv_heads == 0
        assert self.d_model == self.n_heads * self.head_dim


# Named configs. `tiny-*` variants exist for Table 2's model sweep: identical
# shape, different sensitivity engineering (robust ≈ Llama-3.1-8B's tolerance
# profile; sensitive ≈ Qwen2.5-7B's, which collapses at K4 per-token).
CONFIGS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    CONFIGS[cfg.name] = cfg
    return cfg


_register(ModelConfig("tiny", 4, 128, 4, 2, 32, 256, 64, seed=0))
_register(ModelConfig("tiny-robust", 4, 128, 4, 2, 32, 256, 64, seed=1,
                      outlier_max=3.0, temp_max=4.0))
_register(ModelConfig("tiny-sensitive", 4, 128, 4, 2, 32, 256, 64, seed=2,
                      outlier_max=48.0, temp_max=1.2))
_register(ModelConfig("small", 8, 256, 4, 2, 64, 512, 512, seed=3))
_register(ModelConfig("base", 12, 512, 8, 4, 64, 1024, 1024, seed=4))


# ---------------------------------------------------------------------------
# Sensitivity-engineering profiles
# ---------------------------------------------------------------------------


def sensitivity_profiles(cfg: ModelConfig) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-layer outlier magnitudes, per-(layer, head) sharpness temps, and
    per-layer outlier channel indices.

    Outliers drive the per-channel-vs-per-token key error gap (paper Table 9);
    sharpness drives the streaming-vs-retrieval robustness split (Lemma 1):
    high-temp heads have a dominating key token and are robust, low-temp
    (diffuse) heads shift under low-bit key quantization.
    """
    rng = np.random.default_rng(cfg.seed + 1000)
    outlier = rng.permutation(
        np.geomspace(1.0, cfg.outlier_max, cfg.n_layers)
    ).astype(np.float32)
    temps = np.stack(
        [
            rng.permutation(np.geomspace(0.5, cfg.temp_max, cfg.n_heads))
            for _ in range(cfg.n_layers)
        ]
    ).astype(np.float32)
    n_out = max(1, cfg.head_dim // 16)  # outlier channels per kv head
    chans = np.stack(
        [
            np.concatenate(
                [
                    rng.choice(cfg.head_dim, size=n_out, replace=False) + h * cfg.head_dim
                    for h in range(cfg.n_kv_heads)
                ]
            )
            for _ in range(cfg.n_layers)
        ]
    ).astype(np.int64)
    return outlier, temps, chans


LAYER_WEIGHT_NAMES = ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")


def layer_weight_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, hq, hkv, dh, f = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    return {
        "ln1": (d,),
        "wq": (d, hq * dh),
        "wk": (d, hkv * dh),
        "wv": (d, hkv * dh),
        "wo": (hq * dh, d),
        "ln2": (d,),
        "w1": (d, f),
        "w2": (f, d),
    }


def init_weights(cfg: ModelConfig) -> Dict[str, np.ndarray]:
    """Deterministic weight init with sensitivity engineering applied."""
    rng = np.random.default_rng(cfg.seed)
    outlier, temps, chans = sensitivity_profiles(cfg)
    d = cfg.d_model
    w: Dict[str, np.ndarray] = {}
    w["embed"] = (rng.standard_normal((cfg.vocab, d)) / math.sqrt(d)).astype(np.float32)
    w["ln_f"] = np.ones(d, dtype=np.float32)
    shapes = layer_weight_shapes(cfg)
    for l in range(cfg.n_layers):
        for nm in LAYER_WEIGHT_NAMES:
            shp = shapes[nm]
            if nm.startswith("ln"):
                t = np.ones(shp, dtype=np.float32)
            else:
                t = (rng.standard_normal(shp) / math.sqrt(shp[0])).astype(np.float32)
            if nm == "wk":
                # key-channel outliers: a few output channels per kv head get
                # magnitude outlier[l] (the Fig. 7 channel-outlier structure).
                t[:, chans[l]] *= outlier[l]
            if nm == "wq":
                # per-head attention sharpness: scales q so qk^T logits of
                # head h are multiplied by temps[l, h].
                th = np.repeat(temps[l], cfg.head_dim)
                t *= th[None, :]
            if nm in ("wo", "w2"):
                t *= 0.25  # damp the residual stream growth over layers
            w[f"layer{l}.{nm}"] = t
    return w


# ---------------------------------------------------------------------------
# Model math (shared by all layer-step variants)
# ---------------------------------------------------------------------------


def rmsnorm(x, g, eps):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope_tables(cfg: ModelConfig, positions):
    """positions: [B, T] int32 -> (cos, sin) each [B, T, Dh/2]."""
    half = cfg.head_dim // 2
    freqs = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, H, T, Dh]; cos/sin: [B, T, Dh/2] (split-half convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c, s = cos[:, None], sin[:, None]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _pad_seq(x, total_pad):
    if total_pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, total_pad), (0, 0)))


def _attention_mask(cfg, batch, t, s_max, with_residual, cache_len, res_len, padded_total):
    """Additive mask [B, T, padded_total] over [cache | residual? | new | pad]."""
    r = cfg.residual if with_residual else 0
    j = jnp.arange(padded_total)
    valid_cache = (j[None, :] < cache_len[:, None]) & (j[None, :] < s_max)  # [B, P]
    valid = jnp.broadcast_to(valid_cache[:, None, :], (batch, t, padded_total))
    if with_residual:
        in_res = (j >= s_max) & (j < s_max + r)
        valid_res = in_res[None, :] & ((j - s_max)[None, :] < res_len[:, None])
        valid = valid | jnp.broadcast_to(valid_res[:, None, :], valid.shape)
    new0 = s_max + r
    ti = jnp.arange(t)
    valid_new = (j[None, :] >= new0) & ((j - new0)[None, :] <= ti[:, None]) & (
        j[None, :] < new0 + t
    )
    valid = valid | jnp.broadcast_to(valid_new[None], valid.shape)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


# Attention kernel selection (§Perf L1-1): the fused whole-sequence kernel
# collapses the (B, Hq, seq-blocks) interpret-mode grid to (B,), which is the
# dominant CPU-PJRT perf lever; set KVTUNER_FLASH=1 at AOT time to lower the
# flash (online-softmax, seq-blocked) kernel instead — the TPU-shaped layout.
import os

USE_FLASH = os.environ.get("KVTUNER_FLASH", "0") == "1"


def _layer_core(cfg, x, q, k_full, v_full, mask, wo, ln2, w1, w2):
    """Attention output projection + MLP, given assembled K/V and mask."""
    b, t = x.shape[0], x.shape[1]
    if USE_FLASH:
        attn = flash_attention(q, k_full, v_full, mask, block_k=ATTN_BLOCK)
    else:
        attn = fused_attention(q, k_full, v_full, mask)
    o = attn.transpose(0, 2, 1, 3).reshape(b, t, cfg.n_heads * cfg.head_dim)
    x = x + o @ wo
    h = rmsnorm(x, ln2, cfg.rms_eps)
    x = x + jax.nn.gelu(h @ w1) @ w2
    return x


def _qkv(cfg, x, pos, ln1, wq, wk, wv):
    b, t, _ = x.shape
    h = rmsnorm(x, ln1, cfg.rms_eps)
    q = (h @ wq).reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = (h @ wk).reshape(b, t, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = (h @ wv).reshape(b, t, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    positions = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    cos, sin = rope_tables(cfg, positions)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


# ---------------------------------------------------------------------------
# Layer-step variants (the AOT units)
# ---------------------------------------------------------------------------


def make_layer_step_fp(cfg: ModelConfig, batch: int, t: int, s_max: int):
    """Full-precision cache layer step: the KV16 reference arm."""

    def fn(x, pos, cache_len, ln1, wq, wk, wv, wo, ln2, w1, w2, k_fp, v_fp):
        q, k_new, v_new = _qkv(cfg, x, pos, ln1, wq, wk, wv)
        total = s_max + t
        pad = (-total) % ATTN_BLOCK
        k_full = _pad_seq(jnp.concatenate([k_fp, k_new], axis=2), pad)
        v_full = _pad_seq(jnp.concatenate([v_fp, v_new], axis=2), pad)
        res_len = jnp.zeros_like(cache_len)
        mask = _attention_mask(cfg, batch, t, s_max, False, cache_len, res_len, total + pad)
        y = _layer_core(cfg, x, q, k_full, v_full, mask, wo, ln2, w1, w2)
        return y, k_new, v_new

    return fn


def make_layer_step_token(cfg: ModelConfig, kb: int, vb: int, batch: int, t: int, s_max: int):
    """Per-token-asym layer step. Cache codes in, new-token codes out."""

    def fn(
        x, pos, cache_len,
        ln1, wq, wk, wv, wo, ln2, w1, w2,
        k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
    ):
        q, k_new, v_new = _qkv(cfg, x, pos, ln1, wq, wk, wv)
        k_cache = dequantize(k_codes, k_scale, k_zero, kb, "per-token-asym", cfg.head_dim)
        v_cache = dequantize(v_codes, v_scale, v_zero, vb, "per-token-asym", cfg.head_dim)
        total = s_max + t
        pad = (-total) % ATTN_BLOCK
        k_full = _pad_seq(jnp.concatenate([k_cache, k_new], axis=2), pad)
        v_full = _pad_seq(jnp.concatenate([v_cache, v_new], axis=2), pad)
        res_len = jnp.zeros_like(cache_len)
        mask = _attention_mask(cfg, batch, t, s_max, False, cache_len, res_len, total + pad)
        y = _layer_core(cfg, x, q, k_full, v_full, mask, wo, ln2, w1, w2)
        kc, ks, kz = quantize_chunk(k_new, kb, "per-token-asym")
        vc, vs, vz = quantize_chunk(v_new, vb, "per-token-asym")
        return y, kc, ks, kz, vc, vs, vz

    return fn


def make_layer_step_kivi(cfg: ModelConfig, kb: int, vb: int, batch: int, t: int, s_max: int):
    """KIVI layer step: key per-channel cache + fp residual ring; value
    per-token cache + fp residual ring. New-token K/V returned fp (the Rust
    cache manager appends them to the residual and commits groups of G)."""

    def fn(
        x, pos, cache_len, res_len,
        ln1, wq, wk, wv, wo, ln2, w1, w2,
        k_codes, k_scale, k_zero, v_codes, v_scale, v_zero,
        k_res, v_res,
    ):
        q, k_new, v_new = _qkv(cfg, x, pos, ln1, wq, wk, wv)
        k_cache = dequantize(
            k_codes, k_scale, k_zero, kb, "per-channel-asym", cfg.head_dim, cfg.group
        )
        v_cache = dequantize(v_codes, v_scale, v_zero, vb, "per-token-asym", cfg.head_dim)
        total = s_max + cfg.residual + t
        pad = (-total) % ATTN_BLOCK
        k_full = _pad_seq(jnp.concatenate([k_cache, k_res, k_new], axis=2), pad)
        v_full = _pad_seq(jnp.concatenate([v_cache, v_res, v_new], axis=2), pad)
        mask = _attention_mask(cfg, batch, t, s_max, True, cache_len, res_len, total + pad)
        y = _layer_core(cfg, x, q, k_full, v_full, mask, wo, ln2, w1, w2)
        return y, k_new, v_new

    return fn


def make_quantize_chunk(cfg: ModelConfig, bits: int, mode: str, batch: int, chunk: int):
    """Standalone commit executable: fp chunk -> packed codes + scale/zero."""

    def fn(x):
        return quantize_chunk(x, bits, mode)

    return fn


def make_embed(cfg: ModelConfig, batch: int, t: int):
    def fn(ids, embed):
        return (jnp.take(embed, ids, axis=0),)

    return fn


def make_lm_head(cfg: ModelConfig, batch: int):
    def fn(x, ln_f, embed):
        h = rmsnorm(x, ln_f, cfg.rms_eps)
        logits = h @ embed.T
        return (logits, jnp.argmax(logits, axis=-1).astype(jnp.int32))

    return fn


# ---------------------------------------------------------------------------
# Input specs (shared with aot.py so the manifest matches the lowering)
# ---------------------------------------------------------------------------


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _u8(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint8)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def weight_specs(cfg: ModelConfig) -> List[Tuple[str, jax.ShapeDtypeStruct]]:
    shapes = layer_weight_shapes(cfg)
    return [(nm, _f32(*shapes[nm])) for nm in LAYER_WEIGHT_NAMES]


def cache_specs(cfg: ModelConfig, mode: str, kb: int, vb: int, batch: int, s_max: int):
    """Cache-tensor (name, spec) list for a layer step, per mode."""
    h, dh, g = cfg.n_kv_heads, cfg.head_dim, cfg.group
    if mode == "fp":
        return [
            ("k_fp", _f32(batch, h, s_max, dh)),
            ("v_fp", _f32(batch, h, s_max, dh)),
        ]
    specs = [("k_codes", _u8(batch, h, s_max, packed_width(dh, kb)))]
    if mode == "token":
        specs += [("k_scale", _f32(batch, h, s_max)), ("k_zero", _f32(batch, h, s_max))]
    else:  # kivi: key per-channel
        ng = s_max // g
        specs += [("k_scale", _f32(batch, h, ng, dh)), ("k_zero", _f32(batch, h, ng, dh))]
    specs += [
        ("v_codes", _u8(batch, h, s_max, packed_width(dh, vb))),
        ("v_scale", _f32(batch, h, s_max)),
        ("v_zero", _f32(batch, h, s_max)),
    ]
    if mode == "kivi":
        specs += [
            ("k_res", _f32(batch, h, cfg.residual, dh)),
            ("v_res", _f32(batch, h, cfg.residual, dh)),
        ]
    return specs


def layer_step_specs(cfg: ModelConfig, mode: str, kb: int, vb: int, batch: int, t: int, s_max: int):
    specs = [("x", _f32(batch, t, cfg.d_model)), ("pos", _i32(batch)), ("cache_len", _i32(batch))]
    if mode == "kivi":
        specs.append(("res_len", _i32(batch)))
    specs += weight_specs(cfg)
    specs += cache_specs(cfg, mode, kb, vb, batch, s_max)
    return specs


def make_layer_step(cfg: ModelConfig, mode: str, kb: int, vb: int, batch: int, t: int, s_max: int):
    if mode == "fp":
        return make_layer_step_fp(cfg, batch, t, s_max)
    if mode == "token":
        return make_layer_step_token(cfg, kb, vb, batch, t, s_max)
    if mode == "kivi":
        return make_layer_step_kivi(cfg, kb, vb, batch, t, s_max)
    raise ValueError(f"unknown mode {mode!r}")
