"""Layer-step consistency: quantized variants vs the fp reference arm."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref
from compile.kernels.packing import packed_width

CFG = M.CONFIGS["tiny"]
B, S = 2, 256
H, DH, G, R = CFG.n_kv_heads, CFG.head_dim, CFG.group, CFG.residual


@pytest.fixture(scope="module")
def weights():
    w = M.init_weights(CFG)
    return [jnp.asarray(w[f"layer1.{n}"]) for n in M.LAYER_WEIGHT_NAMES]


def _fp_cache(seed, n_tok):
    k = jax.random.normal(jax.random.PRNGKey(seed), (B, H, S, DH), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, H, S, DH), jnp.float32)
    mask = (jnp.arange(S) < n_tok)[None, None, :, None]
    return k * mask, v * mask


def _token_cache_from_fp(k, v, kb, vb):
    kc, ks, kz = ref.quantize_chunk_ref(k, kb, "per-token-asym")
    vc, vs, vz = ref.quantize_chunk_ref(v, vb, "per-token-asym")
    return kc, ks, kz, vc, vs, vz


def test_token_8bit_close_to_fp(weights):
    """K8V8 per-token cache ≈ fp cache at the layer-output level (paper: KV8
    is lossless; expect small relative error)."""
    n_tok = 64
    k, v = _fp_cache(0, n_tok)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, 1, CFG.d_model))
    pos = jnp.full((B,), n_tok, jnp.int32)
    clen = jnp.full((B,), n_tok, jnp.int32)

    y_fp, kn, vn = M.make_layer_step(CFG, "fp", 16, 16, B, 1, S)(x, pos, clen, *weights, k, v)
    caches = _token_cache_from_fp(k, v, 8, 8)
    out = M.make_layer_step(CFG, "token", 8, 8, B, 1, S)(x, pos, clen, *weights, *caches)
    rel = float(jnp.linalg.norm(out[0] - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.02, rel
    # new-token K/V agree after dequantization
    kn_hat = ref.dequantize_ref(out[1], out[2], out[3], 8, "per-token-asym", DH)
    np.testing.assert_allclose(np.asarray(kn_hat), np.asarray(kn), atol=0.05)


def test_token_error_grows_as_bits_drop(weights):
    n_tok = 128
    k, v = _fp_cache(2, n_tok)
    x = jax.random.normal(jax.random.PRNGKey(6), (B, 1, CFG.d_model))
    pos = clen = jnp.full((B,), n_tok, jnp.int32)
    y_fp, _, _ = M.make_layer_step(CFG, "fp", 16, 16, B, 1, S)(x, pos, clen, *weights, k, v)
    errs = []
    for bits in (8, 4, 2):
        caches = _token_cache_from_fp(k, v, bits, bits)
        out = M.make_layer_step(CFG, "token", bits, bits, B, 1, S)(x, pos, clen, *weights, *caches)
        errs.append(float(jnp.linalg.norm(out[0] - y_fp) / jnp.linalg.norm(y_fp)))
    assert errs[0] < errs[1] < errs[2], errs


def test_kivi_residual_only_matches_fp(weights):
    """Empty quantized cache + all tokens in the fp residual == fp attention
    over those tokens exactly (the residual path adds no quant error)."""
    n_res = 16
    x = jax.random.normal(jax.random.PRNGKey(7), (B, 1, CFG.d_model))
    kf = jax.random.normal(jax.random.PRNGKey(8), (B, H, R, DH))
    vf = jax.random.normal(jax.random.PRNGKey(9), (B, H, R, DH))
    pos = jnp.full((B,), n_res, jnp.int32)
    clen = jnp.zeros((B,), jnp.int32)
    rlen = jnp.full((B,), n_res, jnp.int32)

    ngs = S // G
    kc = jnp.zeros((B, H, S, packed_width(DH, 4)), jnp.uint8)
    ks = jnp.ones((B, H, ngs, DH))
    kz = jnp.zeros((B, H, ngs, DH))
    vc = jnp.zeros((B, H, S, packed_width(DH, 2)), jnp.uint8)
    vs, vz = jnp.ones((B, H, S)), jnp.zeros((B, H, S))
    y_kivi, kn, vn = M.make_layer_step(CFG, "kivi", 4, 2, B, 1, S)(
        x, pos, clen, rlen, *weights, kc, ks, kz, vc, vs, vz, kf, vf
    )

    # fp arm: put the same tokens in the fp cache
    k_fp = jnp.zeros((B, H, S, DH)).at[:, :, :R].set(kf)
    v_fp = jnp.zeros((B, H, S, DH)).at[:, :, :R].set(vf)
    y_fp, kn2, vn2 = M.make_layer_step(CFG, "fp", 16, 16, B, 1, S)(
        x, pos, jnp.full((B,), n_res, jnp.int32), *weights, k_fp, v_fp
    )
    np.testing.assert_allclose(np.asarray(y_kivi), np.asarray(y_fp), atol=1e-4)
    np.testing.assert_allclose(np.asarray(kn), np.asarray(kn2), atol=1e-5)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 4, DH))
    pos = jnp.array([[3, 4, 5, 6]], jnp.int32)
    cos, sin = M.rope_tables(CFG, pos)
    y = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, DH))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, DH))

    def dot_at(pi, pj):
        ci, si = M.rope_tables(CFG, jnp.array([[pi]], jnp.int32))
        cj, sj = M.rope_tables(CFG, jnp.array([[pj]], jnp.int32))
        return float(jnp.sum(M.apply_rope(q, ci, si) * M.apply_rope(k, cj, sj)))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-3


def test_sensitivity_profiles_deterministic_and_heterogeneous():
    o1, t1, c1 = M.sensitivity_profiles(CFG)
    o2, t2, c2 = M.sensitivity_profiles(CFG)
    np.testing.assert_array_equal(o1, o2)
    np.testing.assert_array_equal(t1, t2)
    assert o1.max() / o1.min() > 4  # heterogeneous layers
    assert len(set(np.round(o1, 3))) == CFG.n_layers
    # different seeds -> different profiles
    o3, _, _ = M.sensitivity_profiles(M.CONFIGS["tiny-sensitive"])
    assert not np.allclose(o1, o3)


def test_outliers_present_in_wk():
    w = M.init_weights(CFG)
    _, _, chans = M.sensitivity_profiles(CFG)
    outlier, _, _ = M.sensitivity_profiles(CFG)
    l = int(np.argmax(outlier))
    wk = w[f"layer{l}.wk"]
    col_norm = np.linalg.norm(wk, axis=0)
    assert col_norm[chans[l]].mean() > 3 * np.median(col_norm)
