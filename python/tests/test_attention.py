"""Flash-attention Pallas kernel vs naive oracle (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention
from compile.kernels.ref import attention_ref

NEG = -1e30


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def _len_mask(b, t, s, lens):
    j = jnp.arange(s)[None, None, :]
    return jnp.where(j < jnp.asarray(lens)[:, None, None], 0.0, NEG) * jnp.ones((b, t, s))


@given(
    b=st.integers(1, 2),
    hq=st.sampled_from([2, 4]),
    gqa=st.sampled_from([1, 2]),
    t=st.sampled_from([1, 4, 8]),
    s=st.sampled_from([64, 128, 256]),
    dh=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_flash_matches_ref(b, hq, gqa, t, s, dh, seed):
    hkv = hq // gqa
    q = _rand((b, hq, t, dh), seed)
    k = _rand((b, hkv, s, dh), seed + 1)
    v = _rand((b, hkv, s, dh), seed + 2)
    lens = np.random.default_rng(seed).integers(1, s + 1, size=b)
    mask = _len_mask(b, t, s, lens)
    out = flash_attention(q, k, v, mask, block_k=64)
    exp = attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-4)


def test_flash_single_valid_token():
    """With exactly one valid key, output == that key's value row (any head)."""
    b, hq, hkv, t, s, dh = 1, 2, 1, 1, 64, 32
    q = _rand((b, hq, t, dh), 0) * 10
    k = _rand((b, hkv, s, dh), 1)
    v = _rand((b, hkv, s, dh), 2)
    mask = _len_mask(b, t, s, [1])
    out = flash_attention(q, k, v, mask, block_k=64)
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]), np.asarray(v[0, 0, 0]), atol=1e-5)


def test_flash_causal_within_new_tokens():
    """A fully-causal T x T mask equals per-row truncated attention."""
    b, h, t, dh = 1, 2, 8, 32
    q = _rand((b, h, t, dh), 3)
    k = _rand((b, h, t, dh), 4)
    v = _rand((b, h, t, dh), 5)
    ti = jnp.arange(t)
    causal = jnp.where(ti[None, :] <= ti[:, None], 0.0, NEG)[None]
    out = flash_attention(q, k, v, causal * jnp.ones((b, t, t)), block_k=8)
    for row in range(t):
        sub = attention_ref(
            q[:, :, row : row + 1], k[:, :, : row + 1], v[:, :, : row + 1],
            jnp.zeros((b, 1, row + 1)),
        )
        np.testing.assert_allclose(
            np.asarray(out[:, :, row]), np.asarray(sub[:, :, 0]), atol=2e-4
        )


def test_flash_block_boundary_independence():
    """Result is identical for any block size dividing S (online softmax)."""
    b, h, t, s, dh = 1, 2, 2, 128, 32
    q, k, v = _rand((b, h, t, dh), 6), _rand((b, h, s, dh), 7), _rand((b, h, s, dh), 8)
    mask = _len_mask(b, t, s, [100])
    outs = [
        np.asarray(flash_attention(q, k, v, mask, block_k=blk)) for blk in (32, 64, 128)
    ]
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)


def test_flash_gqa_head_mapping():
    """Query head h must attend to kv head h // (Hq/Hkv): make kv heads very
    different and check each query group tracks its own kv head."""
    b, hq, hkv, t, s, dh = 1, 4, 2, 1, 64, 32
    q = jnp.zeros((b, hq, t, dh))  # uniform attention
    k = _rand((b, hkv, s, dh), 9)
    v = jnp.stack(
        [jnp.full((s, dh), 1.0), jnp.full((s, dh), -1.0)], axis=0
    )[None]
    mask = jnp.zeros((b, t, s))
    out = np.asarray(flash_attention(q, k, v, mask, block_k=64))
    assert np.allclose(out[0, 0], 1.0) and np.allclose(out[0, 1], 1.0)
    assert np.allclose(out[0, 2], -1.0) and np.allclose(out[0, 3], -1.0)
