"""Pallas quantization kernels vs the pure-jnp oracle (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref
from compile.kernels.packing import packed_width

MODES = st.sampled_from(["per-token-asym", "per-channel-asym"])
BITS = st.sampled_from([2, 4, 8])


def _rand(shape, seed, scale=1.0, outlier=None):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32) * scale
    if outlier:
        x[..., 0] *= outlier  # channel-0 outlier
    return jnp.asarray(x)


@given(
    mode=MODES, bits=BITS,
    b=st.integers(1, 2), h=st.integers(1, 3),
    g=st.sampled_from([1, 8, 32]), dh=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_quantize_matches_ref(mode, bits, b, h, g, dh, seed):
    x = _rand((b, h, g, dh), seed)
    c, s, z = quant.quantize_chunk(x, bits, mode)
    cr, sr, zr = ref.quantize_chunk_ref(x, bits, mode)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-6)


@given(
    mode=MODES, bits=BITS,
    s=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_dequantize_matches_ref(mode, bits, s, seed):
    b, h, dh, g = 1, 2, 32, 32
    x = _rand((b, h, s, dh), seed)
    # build a realistic cache: quantize group by group like the Rust manager
    chunks = [ref.quantize_chunk_ref(x[:, :, i : i + g], bits, mode) for i in range(0, s, g)]
    codes = jnp.concatenate([c for c, _, _ in chunks], axis=2)
    if mode == "per-token-asym":
        scale = jnp.concatenate([sc for _, sc, _ in chunks], axis=2)
        zero = jnp.concatenate([z for _, _, z in chunks], axis=2)
    else:
        scale = jnp.stack([sc for _, sc, _ in chunks], axis=2)
        zero = jnp.stack([z for _, _, z in chunks], axis=2)
    d = quant.dequantize(codes, scale, zero, bits, mode, dh, g)
    dr = ref.dequantize_ref(codes, scale, zero, bits, mode, dh, g)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), atol=1e-5)


@given(mode=MODES, bits=BITS, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_fake_quant_error_bound(mode, bits, seed):
    """|x - dq(q(x))| <= scale/2 + eps elementwise (round-to-nearest)."""
    x = _rand((1, 2, 32, 32), seed, scale=3.0)
    _, scale, _ = ref.quantize_chunk_ref(x, bits, mode)
    xhat = ref.fake_quant_ref(x, bits, mode)
    if mode == "per-token-asym":
        bound = np.asarray(scale)[..., None] * (0.5 + 1e-4) + 1e-6
    else:
        bound = np.asarray(scale)[:, :, None, :] * (0.5 + 1e-4) + 1e-6
    err = np.abs(np.asarray(x - xhat))
    np.testing.assert_array_less(err, np.broadcast_to(bound, err.shape))


def test_error_monotone_in_bits():
    """Mean |x - x̂| strictly shrinks as precision grows (paper Table 9)."""
    x = _rand((2, 2, 64, 64), seed=7, scale=2.0)
    for mode in ("per-token-asym", "per-channel-asym"):
        errs = [
            float(jnp.mean(jnp.abs(x - ref.fake_quant_ref(x, bits, mode))))
            for bits in (2, 4, 8)
        ]
        assert errs[0] > errs[1] > errs[2], (mode, errs)


def test_channel_outliers_favor_per_channel():
    """With strong channel outliers, per-channel-asym key error is far lower
    than per-token-asym at the same precision (paper Sec. 4.2 / Table 9)."""
    x = _rand((1, 2, 64, 64), seed=11, outlier=30.0)
    e_tok = float(jnp.mean(jnp.abs(x - ref.fake_quant_ref(x, 4, "per-token-asym"))))
    e_ch = float(jnp.mean(jnp.abs(x - ref.fake_quant_ref(x, 4, "per-channel-asym"))))
    assert e_ch < e_tok * 0.5, (e_ch, e_tok)


def test_packed_shapes():
    x = _rand((1, 1, 32, 64), seed=0)
    for bits in (2, 4, 8):
        c, s, z = quant.quantize_chunk(x, bits, "per-token-asym")
        assert c.shape == (1, 1, 32, packed_width(64, bits))
        assert s.shape == z.shape == (1, 1, 32)
        c, s, z = quant.quantize_chunk(x, bits, "per-channel-asym")
        assert c.shape == (1, 1, 32, packed_width(64, bits))
        assert s.shape == z.shape == (1, 1, 64)
