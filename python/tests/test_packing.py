"""Property tests for bit-packing (hypothesis over shapes/bits/values)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.packing import pack_codes, packed_width, unpack_codes

BITS = st.sampled_from([2, 4, 8])


@given(
    bits=BITS,
    b=st.integers(1, 3),
    s=st.integers(1, 9),
    dh_mult=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_pack_unpack_roundtrip(bits, b, s, dh_mult, seed):
    dh = dh_mult * (8 // bits) * 4  # always packable
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2**bits, size=(b, s, dh), dtype=np.uint8)
    packed = pack_codes(jnp.asarray(codes), bits)
    assert packed.shape == (b, s, packed_width(dh, bits))
    assert packed.dtype == jnp.uint8
    back = unpack_codes(packed, bits, dh)
    np.testing.assert_array_equal(np.asarray(back), codes)


def test_packed_width_values():
    assert packed_width(64, 8) == 64
    assert packed_width(64, 4) == 32
    assert packed_width(64, 2) == 16
    assert packed_width(32, 2) == 8


def test_packed_width_rejects_bad_bits():
    with pytest.raises(ValueError):
        packed_width(64, 3)
    with pytest.raises(ValueError):
        packed_width(3, 2)


def test_pack_is_dense():
    """Every byte of the packed buffer carries information (no padding)."""
    dh = 16
    for bits in (2, 4):
        codes = jnp.full((1, dh), (1 << bits) - 1, dtype=jnp.uint8)
        packed = pack_codes(codes, bits)
        assert (np.asarray(packed) == 0xFF).all()


def test_unpack_channel_order():
    """Channel d lives at byte d//per_byte, bit-offset bits*(d%per_byte)."""
    bits, dh = 4, 8
    codes = jnp.arange(dh, dtype=jnp.uint8)[None]
    packed = np.asarray(pack_codes(codes, bits))[0]
    assert packed[0] == 0x10  # ch0=0 low nibble, ch1=1 high nibble
    assert packed[1] == 0x32
    back = unpack_codes(jnp.asarray(packed)[None], bits, dh)
    np.testing.assert_array_equal(np.asarray(back)[0], np.arange(dh))
