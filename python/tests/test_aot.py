"""AOT manifest sanity: shapes in manifest match a fresh lowering."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_pairs(manifest):
    layers = [a for a in manifest["artifacts"] if a["kind"] == "layer"]
    for mode in ("token", "kivi"):
        pairs = {
            (a["k_bits"], a["v_bits"])
            for a in layers
            if a["mode"] == mode and a["batch"] == 1 and a["t"] == 1
        }
        assert pairs == {(k, v) for k in (8, 4, 2) for v in (8, 4, 2)}, mode
    assert any(a["mode"] == "fp" for a in layers)


def test_manifest_files_exist(manifest):
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(ART, a["file"])), a["name"]
    for m in manifest["models"].values():
        assert os.path.exists(os.path.join(ART, m["weights"]))


def test_weights_bin_matches_init(manifest):
    """weights-tiny.bin round-trips init_weights exactly."""
    cfg = M.CONFIGS["tiny"]
    entry = manifest["models"]["tiny"]
    blob = np.fromfile(os.path.join(ART, entry["weights"]), dtype=np.float32)
    w = M.init_weights(cfg)
    for nm in ("embed", "ln_f", "layer0.wk", f"layer{cfg.n_layers - 1}.w2"):
        t = entry["tensors"][nm]
        size = int(np.prod(t["shape"]))
        got = blob[t["offset"] : t["offset"] + size].reshape(t["shape"])
        np.testing.assert_array_equal(got, w[nm])


def test_manifest_input_shapes_match_specs(manifest):
    cfg = M.CONFIGS["tiny"]
    a = next(
        x for x in manifest["artifacts"]
        if x["kind"] == "layer" and x["mode"] == "kivi"
        and x["k_bits"] == 4 and x["v_bits"] == 2 and x["batch"] == 1 and x["t"] == 1
    )
    specs = M.layer_step_specs(cfg, "kivi", 4, 2, 1, 1, a["s_max"])
    assert [i["name"] for i in a["inputs"]] == [n for n, _ in specs]
    for got, (_, spec) in zip(a["inputs"], specs):
        assert tuple(got["shape"]) == spec.shape
        assert got["dtype"] == str(spec.dtype)


def test_hlo_text_parses_as_module(manifest):
    """Every artifact begins with an HloModule header (text format)."""
    for a in manifest["artifacts"][:8]:
        with open(os.path.join(ART, a["file"])) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), a["name"]


def test_stamp_caching(tmp_path, capsys):
    """Second aot invocation with identical params is a no-op."""
    stamp = os.path.join(ART, ".stamp")
    if not os.path.exists(stamp):
        pytest.skip("stamp missing")
    with open(stamp) as f:
        content = f.read()
    assert content.startswith(aot.source_stamp())
