#!/usr/bin/env python3
"""Collect and compare BENCH_JSON bench output.

Every bench prints each result table as a machine-readable line:

    BENCH_JSON {"title": ..., "header": [...], "rows": [[...], ...]}

Two modes:

  collect  <out-with-BENCH_JSON-lines>... -o BENCH_BASELINE.json
      Parse every BENCH_JSON line from the given files (or stdin) and write
      a baseline document. `make bench-baseline` drives this over the full
      artifact-free bench suite.

  compare  <BENCH_BASELINE.json> <out-with-BENCH_JSON-lines>... [--threshold 0.15]
      Match current tables against the baseline and flag perf regressions
      beyond the threshold. Advisory by default (always exits 0 so a noisy
      shared runner cannot red-gate unrelated changes); --strict exits 1 on
      regressions.

Matching is deliberately loose, because table titles embed host parameters
(thread counts, core counts): tables pair up by title prefix (up to the
first '('), rows by their first cell, and columns by header name. Only
clearly perf-directional columns are compared — rate-like columns
('tok/s', 'GiB/s', 'speedup') where higher is better, and latency-like
columns ('ms', 'ns', 'us') where lower is better. Everything else
(equivalent bits, MiB footprints, error metrics) is deterministic output
guarded by tests, not by this comparator.
"""

import argparse
import json
import re
import sys

# keep in sync with SCHEMA_VERSION in rust/src/obs/mod.rs
SCHEMA_VERSION = 2

HIGHER_BETTER = re.compile(r"(tok/s|toks/s|/s\b|/sec\b|speedup|throughput)", re.I)
LOWER_BETTER = re.compile(r"(\bms\b|\bns\b|\bus\b|latency|ttft|tpot|\bovh\b|overhead)", re.I)


def parse_tables(paths):
    """All BENCH_JSON tables from the given files ('-' = stdin), in order."""
    tables = []
    for path in paths:
        fh = sys.stdin if path == "-" else open(path)
        with fh:
            for line in fh:
                if line.startswith("BENCH_JSON "):
                    tables.append(json.loads(line.split(" ", 1)[1]))
    return tables


def title_key(title):
    return title.split("(")[0].strip()


def numeric(cell):
    # tolerate unit-suffixed cells ("12.3%", "4.37x") so overhead and
    # speedup columns participate in the comparison
    text = str(cell).replace(",", "").rstrip("%x")
    try:
        return float(text)
    except ValueError:
        return None


def collect(args):
    tables = parse_tables(args.files or ["-"])
    doc = {
        "schema_version": SCHEMA_VERSION,
        "note": "perf baseline collected by `make bench-baseline`; "
        "compare with python/bench_compare.py",
        "tables": tables,
    }
    with open(args.output, "w") as fh:
        json.dump(doc, fh, indent=1)
        fh.write("\n")
    print(f"[bench-compare] collected {len(tables)} table(s) into {args.output}")
    return 0


def column_direction(header):
    if HIGHER_BETTER.search(header):
        return 1
    if LOWER_BETTER.search(header):
        return -1
    return 0


def compare(args):
    with open(args.baseline) as fh:
        base = json.load(fh)
    if base.get("schema_version") != SCHEMA_VERSION:
        print(
            f"[bench-compare] WARNING: baseline schema_version "
            f"{base.get('schema_version')} != expected {SCHEMA_VERSION}; "
            f"re-run `make bench-baseline`"
        )
    baseline = {title_key(t["title"]): t for t in base.get("tables", [])}
    if not baseline:
        print(
            "[bench-compare] baseline has no tables yet — run `make bench-baseline` "
            "on a reference host and commit BENCH_BASELINE.json to arm this check"
        )
        return 0
    current = parse_tables(args.files)
    if not current:
        print("[bench-compare] no BENCH_JSON lines in the current output")
        return 0

    regressions, compared = [], 0
    for table in current:
        key = title_key(table["title"])
        ref = baseline.get(key)
        if ref is None:
            print(f"[bench-compare] no baseline for {key!r} (skipped)")
            continue
        ref_rows = {r[0]: r for r in ref["rows"]}
        for row in table["rows"]:
            ref_row = ref_rows.get(row[0])
            if ref_row is None:
                continue
            for ci, header in enumerate(table["header"]):
                direction = column_direction(header)
                if direction == 0 or ci >= len(ref["header"]) or header != ref["header"][ci]:
                    continue
                now, was = numeric(row[ci]), numeric(ref_row[ci])
                if now is None or was is None or was == 0:
                    continue
                compared += 1
                change = (now - was) / was
                if change * direction < -args.threshold:
                    regressions.append(
                        f"{key} / {row[0]} / {header}: {was:g} -> {now:g} "
                        f"({change * 100:+.1f}%)"
                    )
    if regressions:
        print(
            f"[bench-compare] {len(regressions)} regression(s) beyond "
            f"{args.threshold * 100:.0f}% (of {compared} compared cells):"
        )
        for r in regressions:
            print(f"  REGRESSION {r}")
    else:
        print(f"[bench-compare] no regressions beyond {args.threshold * 100:.0f}% "
              f"({compared} cells compared)")
    return 1 if (regressions and args.strict) else 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="mode", required=True)
    c = sub.add_parser("collect", help="gather BENCH_JSON lines into a baseline")
    c.add_argument("files", nargs="*", help="bench output files ('-' = stdin)")
    c.add_argument("-o", "--output", default="BENCH_BASELINE.json")
    d = sub.add_parser("compare", help="flag regressions vs a baseline")
    d.add_argument("baseline")
    d.add_argument("files", nargs="+", help="bench output files ('-' = stdin)")
    d.add_argument("--threshold", type=float, default=0.15)
    d.add_argument("--strict", action="store_true", help="exit 1 on regressions")
    args = ap.parse_args()
    sys.exit(collect(args) if args.mode == "collect" else compare(args))


if __name__ == "__main__":
    main()
